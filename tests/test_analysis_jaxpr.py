"""Unit tests for the jaxpr invariant checkers on toy functions.

Each checker gets a deliberate violation (fires) and a contract-abiding
twin (clean), traced with jax.make_jaxpr on tiny shapes — no Engine or
trainer fixtures, so these run in milliseconds and pin the checker
semantics independently of the real trace targets.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import jaxpr as jx

VOCAB, DIM = 32, 8
TABLE = (VOCAB, DIM)


def _codes():
    return jnp.zeros(TABLE, jnp.int8)


# ---------------------------------------------------------- no-f32-table


class TestNoF32Table:
    def test_full_table_dequant_fires(self):
        def bad(codes, step, ids):
            table = codes.astype(jnp.float32) * step  # whole-table image
            return table[ids]

        closed = jax.make_jaxpr(bad)(
            _codes(), jnp.float32(0.1), jnp.zeros((3,), jnp.int32))
        found = jx.check_no_f32_table(closed, {TABLE}, "toy")
        assert found and found[0].rule == "jaxpr-no-f32-table"

    def test_per_row_dequant_is_clean(self):
        def good(codes, step, ids):
            rows = jnp.take(codes, ids, axis=0)  # gather first
            return rows.astype(jnp.float32) * step

        closed = jax.make_jaxpr(good)(
            _codes(), jnp.float32(0.1), jnp.zeros((3,), jnp.int32))
        assert jx.check_no_f32_table(closed, {TABLE}, "toy") == []

    def test_int8_table_shape_not_flagged(self):
        # The resident int8 table itself is the contract, not a violation.
        def ident(codes):
            return codes + jnp.int8(0)

        closed = jax.make_jaxpr(ident)(_codes())
        assert jx.check_no_f32_table(closed, {TABLE}, "toy") == []

    def test_recurses_into_pjit_subjaxpr(self):
        @jax.jit
        def inner(codes, step):
            return codes.astype(jnp.float32) * step

        def outer(codes, step):
            return inner(codes, step).sum()

        closed = jax.make_jaxpr(outer)(_codes(), jnp.float32(0.1))
        found = jx.check_no_f32_table(closed, {TABLE}, "toy")
        assert found, "checker must walk pjit sub-jaxprs"


# ---------------------------------------------------- codes-dequant-only


class TestCodesDequantOnly:
    def test_scaled_widen_is_clean(self):
        def good(rows, step):
            return rows.astype(jnp.float32) * step

        closed = jax.make_jaxpr(good)(
            jnp.zeros((3, DIM), jnp.int8), jnp.float32(0.1))
        assert jx.check_codes_reach_float_via_dequant(closed, "toy") == []

    def test_unscaled_widen_fires(self):
        def bad(rows, bias):
            return rows.astype(jnp.float32) + bias  # widen w/o scale

        closed = jax.make_jaxpr(bad)(
            jnp.zeros((3, DIM), jnp.int8), jnp.zeros((DIM,), jnp.float32))
        found = jx.check_codes_reach_float_via_dequant(closed, "toy")
        assert found and "without a scale multiply" in found[0].message

    def test_uint8_to_float_always_fires(self):
        def bad(packed, step):
            return packed.astype(jnp.float32) * step  # bytes are not codes

        closed = jax.make_jaxpr(bad)(
            jnp.zeros((3, DIM // 2), jnp.uint8), jnp.float32(0.1))
        found = jx.check_codes_reach_float_via_dequant(closed, "toy")
        assert found and "uint8" in found[0].message

    def test_shape_ops_between_widen_and_mul_are_clean(self):
        def good(rows, step):
            f = rows.astype(jnp.float32)
            return f.reshape(-1) * step  # reshape passes through

        closed = jax.make_jaxpr(good)(
            jnp.zeros((3, DIM), jnp.int8), jnp.float32(0.1))
        assert jx.check_codes_reach_float_via_dequant(closed, "toy") == []


# ------------------------------------------------------ packed-containment


class TestPackedContainment:
    def test_whole_table_unpack_fires(self):
        from repro.core import codestore

        packed = codestore.pack_codes(jnp.zeros(TABLE, jnp.int8), 4)

        def bad(p):
            logical = codestore.unpack_codes(p, 4, DIM)  # [VOCAB, DIM] int8
            return logical.sum()

        closed = jax.make_jaxpr(bad)(packed)
        found = jx.check_packed_stays_packed(closed, {TABLE}, "toy")
        assert found and found[0].rule == "jaxpr-packed-containment"

    def test_per_row_unpack_is_clean(self):
        from repro.core import codestore

        packed = codestore.pack_codes(jnp.zeros(TABLE, jnp.int8), 4)

        def good(p, ids):
            rows = jnp.take(p, ids, axis=0)  # gather packed rows
            return codestore.unpack_codes(rows, 4, DIM).sum()

        closed = jax.make_jaxpr(good)(packed, jnp.zeros((3,), jnp.int32))
        assert jx.check_packed_stays_packed(closed, {TABLE}, "toy") == []


# ----------------------------------------------------------- packed-wire


class TestPackedWire:
    def _trace_psum(self, fn, *args):
        from jax.sharding import PartitionSpec as P

        import repro.dist  # noqa: F401 (shard_map compat adapter)

        mesh = jax.make_mesh((1,), ("data",))
        specs = tuple(P() for _ in args)
        mapped = jax.shard_map(fn, mesh=mesh, in_specs=specs,
                               out_specs=P(), check_vma=False)
        return jax.make_jaxpr(mapped)(*args)

    def test_wide_payload_fires(self):
        def bad(g):
            return jax.lax.psum(g, "data")  # f32 payload on the wire

        closed = self._trace_psum(bad, jnp.zeros((64,), jnp.float32))
        found = jx.check_wire_stays_packed(closed, "toy")
        assert found and found[0].rule == "jaxpr-packed-wire"

    def test_packed_payload_is_clean(self):
        def wire_only(p):
            g = jax.lax.all_gather(p, "data")  # uint8 wire
            return g.astype(jnp.int32).sum(0)

        closed = self._trace_psum(wire_only, jnp.zeros((32,), jnp.uint8))
        assert jx.check_wire_stays_packed(closed, "toy") == []

    def test_scalar_absmax_exempt(self):
        def good(x):
            return jax.lax.pmax(x, "data") if hasattr(jax.lax, "pmax") \
                else jax.lax.psum(x, "data")

        closed = self._trace_psum(good, jnp.float32(1.0))
        assert jx.check_wire_stays_packed(closed, "toy") == []


# -------------------------------------------------------------- walk_eqns


def test_walk_eqns_covers_nested_scan():
    def stepper(carry, x):
        return carry + x * 2.0, carry

    def outer(xs):
        out, _ = jax.lax.scan(stepper, jnp.float32(0.0), xs)
        return out

    closed = jax.make_jaxpr(outer)(jnp.zeros((4,), jnp.float32))
    prims = {e.primitive.name for e in jx.walk_eqns(closed)}
    assert "scan" in prims and "mul" in prims  # mul lives in the sub-jaxpr


# ------------------------------------------------------------ trace targets


def test_target_registry_names_unique_and_complete():
    from repro.analysis.jaxpr.targets import all_targets
    names = [t.name for t in all_targets()]
    assert len(names) == len(set(names))
    for m in ("lpt", "alpt", "qr_lpt", "qr_alpt", "mixed"):
        assert f"engine-ctr/{m}" in names
    assert "collective-sync/bits4" in names
    assert "collective-sync/bits2" in names


@pytest.mark.slow
def test_engine_ctr_targets_hold_no_f32_table():
    """The acceptance-criterion check: every registered integer-table
    method's Engine step is provably free of full-table float
    intermediates.  Slow (builds real engines); the CLI runs the full set.
    """
    from repro.analysis.jaxpr.targets import run_jaxpr_checks
    names = [f"engine-ctr/{m}"
             for m in ("lpt", "alpt", "qr_lpt", "qr_alpt", "mixed")]
    assert run_jaxpr_checks(names=names) == []
