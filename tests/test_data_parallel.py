"""Data-parallel trainer tests (repro.training.data_parallel).

The exactness contract: the n-device shard_map DP step is bitwise
step-for-step equal to the single-device microbatched trainer with
``n_shards == n`` — at sync_bits 32 (deterministic fp32 mean) *and* at 8/4
(SR-compressed int codes; integer psums are associative, SR noise is keyed by
rank).  The compressed path must additionally track the exact path's training
trajectory within the paper's error bound.

Mesh tests run in subprocesses with 8 fake CPU devices (marker: dist); the
wire-byte accounting tests are plain fast tests.
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_prog


# ---------------------------------------------------------------- fast tests


def test_wire_bytes_accounting():
    from repro.dist import collectives

    grads = {
        "table": jax.ShapeDtypeStruct((1000, 16), jnp.float32),
        "w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
    }
    n_elem = 1000 * 16 + 64 * 32
    assert collectives.sync_wire_bytes(grads, 32) == n_elem * 4
    # 8-bit codes: 1 byte/element + one fp32 step scalar per tensor.
    assert collectives.sync_wire_bytes(grads, 8) == n_elem + 8
    # 4-bit codes pack two per byte.
    assert collectives.sync_wire_bytes(grads, 4) == n_elem // 2 + 8
    assert collectives.sync_compression_ratio(grads, 8) >= 3.5
    assert collectives.sync_compression_ratio(grads, 4) >= 7.0
    with pytest.raises(ValueError):
        collectives.sync_wire_bytes(grads, 16)


def test_dp_config_validates_bits():
    from repro.training.data_parallel import DPConfig

    for bits in (32, 8, 4, 2):
        assert DPConfig(sync_bits=bits).sync_bits == bits
    with pytest.raises(ValueError):
        DPConfig(sync_bits=16)


def test_compressed_pmean_stacked_is_psum_over_n():
    from repro.dist import collectives

    stack = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 8))
    key = jax.random.PRNGKey(1)
    total = collectives.compressed_psum_stacked(stack, key, bits=8)
    mean = collectives.compressed_pmean_stacked(stack, key, bits=8)
    np.testing.assert_array_equal(np.asarray(mean), np.asarray(total) / 4.0)
    # Unbiased quantizer: the compressed mean tracks the exact mean within
    # the int8 bound (n * step with shared step = absmax / 127).
    exact = np.asarray(stack).mean(0)
    err = np.abs(np.asarray(mean) - exact).max()
    bound = np.abs(np.asarray(stack)).max() / 127.0 * 1.5
    assert err < bound


# ------------------------------------------------------- mesh (dist) tests


@pytest.mark.dist
def test_dp_ctr_bitwise_matches_microbatched_trainer():
    """8-device DP CTR step == single-device microbatched step, bit for bit,
    for every embedding-method family and at exact AND compressed widths."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.alpt import ALPTConfig
        from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
        from repro.models import embedding as emb_mod
        from repro.models.ctr import DCNConfig
        from repro.training.ctr_trainer import CTRTrainer, TrainerConfig
        from repro.training import data_parallel as dpm

        data_cfg = CTRDatasetConfig(
            name="mini", n_fields=6, cardinalities=(17, 29, 11, 41, 13, 23),
            teacher_rank=4, seed=3,
        )
        data = CTRSynthetic(data_cfg)
        mesh = jax.make_mesh((8,), ("data",))

        def trainer(method):
            spec = emb_mod.EmbeddingSpec(
                method=method, n=data_cfg.n_features, d=8, bits=8,
                init_scale=0.05, alpt=ALPTConfig(bits=8, step_lr=2e-4),
            )
            dcn = DCNConfig(n_fields=data_cfg.n_fields, emb_dim=8,
                            cross_depth=2, mlp_widths=(32, 16))
            return CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn,
                                            lr=1e-3))

        for method, bits in [("fp", 32), ("fp", 8), ("lpt", 32), ("lpt", 8),
                             ("alpt", 32), ("alpt", 8), ("alpt", 4)]:
            tr = trainer(method)
            dp = dpm.DPConfig(sync_bits=bits)
            mesh_step = dpm.make_ctr_dp_step(tr, mesh, dp)
            micro_step = dpm.make_ctr_microbatch_step(tr, 8, dp)
            s_m, s_u = tr.init_state(), tr.init_state()
            for i in range(3):
                ids, labels = data.batch("train", i, 64)
                s_m, m_m = mesh_step(s_m, jnp.asarray(ids), jnp.asarray(labels))
                s_u, m_u = micro_step(s_u, jnp.asarray(ids), jnp.asarray(labels))
                for a, b in zip(jax.tree.leaves(s_m), jax.tree.leaves(s_u)):
                    assert np.array_equal(np.asarray(jax.device_get(a)),
                                          np.asarray(jax.device_get(b))), (
                        method, bits, i, a.shape, a.dtype)
                assert float(m_m["loss"]) == float(m_u["loss"]), (method, bits)
            print(method, bits, "OK", float(m_m["loss"]))
        print("CTR_DP_BITWISE_OK")
        """
    )
    assert "CTR_DP_BITWISE_OK" in run_prog(prog)


@pytest.mark.dist
def test_dp_lm_bitwise_matches_microbatched_trainer():
    """Same contract for the LM trainer (lpt + alpt vocab tables)."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, numpy as np
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.training import lm_trainer
        from repro.training import data_parallel as dpm

        mesh = jax.make_mesh((8,), ("data",))
        for method, bits in [("lpt", 32), ("alpt", 8)]:
            cfg = configs.smoke_config("smollm-135m")
            cfg = dataclasses.replace(cfg, embedding_method=method)
            tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
            batch = concrete_batch(cfg, batch=16, seq=32)
            dp = dpm.DPConfig(sync_bits=bits)
            mesh_step = dpm.make_lm_dp_step(cfg, tcfg, mesh, dp)
            micro_step = dpm.make_lm_microbatch_step(cfg, tcfg, 8, dp)
            s_m = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
            s_u = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
            for i in range(2):
                s_m, m_m = mesh_step(s_m, batch)
                s_u, m_u = micro_step(s_u, batch)
                for a, b in zip(jax.tree.leaves(s_m), jax.tree.leaves(s_u)):
                    assert np.array_equal(np.asarray(jax.device_get(a)),
                                          np.asarray(jax.device_get(b))), (
                        method, bits, i, a.shape, a.dtype)
            assert float(m_m["loss"]) == float(m_u["loss"])
            print(method, bits, "OK", float(m_m["loss"]))
        print("LM_DP_BITWISE_OK")
        """
    )
    assert "LM_DP_BITWISE_OK" in run_prog(prog)


@pytest.mark.dist
def test_dp_compressed_tracks_exact_training():
    """Compressed (8-bit) gradient sync must reproduce the exact-sync
    training trajectory within the paper's error bound: close per-step
    losses, matching final eval metrics, and >= 3.5x wire-byte reduction."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.alpt import ALPTConfig
        from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
        from repro.models import embedding as emb_mod
        from repro.models.ctr import DCNConfig
        from repro.training.ctr_trainer import CTRTrainer, TrainerConfig
        from repro.training import data_parallel as dpm

        data_cfg = CTRDatasetConfig(
            name="mini", n_fields=6, cardinalities=(37, 29, 53, 41, 19, 23),
            teacher_rank=4, seed=5,
        )
        data = CTRSynthetic(data_cfg)
        mesh = jax.make_mesh((8,), ("data",))

        def run(bits):
            spec = emb_mod.EmbeddingSpec(
                method="lpt", n=data_cfg.n_features, d=8, bits=8,
                init_scale=0.05, clip_value=0.1, alpt=ALPTConfig(bits=8),
            )
            dcn = DCNConfig(n_fields=data_cfg.n_fields, emb_dim=8,
                            cross_depth=2, mlp_widths=(32, 16))
            tr = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn,
                                          lr=3e-3, dp_sync_bits=bits))
            step = dpm.make_ctr_dp_step(tr, mesh)
            state = tr.init_state()
            losses = []
            for i in range(40):
                ids, labels = data.batch("train", i, 128)
                state, m = step(state, jnp.asarray(ids), jnp.asarray(labels))
                losses.append(float(m["loss"]))
            ev = tr.evaluate(jax.device_get(state),
                             data.batches("test", 128, 8))
            shapes = dpm.ctr_grad_shapes(tr, tr.init_state(), 16,
                                         data_cfg.n_fields)
            report = dpm.wire_report(shapes, bits)
            return losses, ev, report

        l32, ev32, _ = run(32)
        l8, ev8, rep8 = run(8)
        dloss = max(abs(a - b) for a, b in zip(l32, l8))
        dauc = abs(ev32["auc"] - ev8["auc"])
        print("max dloss", dloss, "dauc", dauc,
              "ratio", rep8["compression_ratio"])
        assert dloss < 0.05, dloss
        assert dauc < 0.02, (ev32, ev8)
        assert rep8["compression_ratio"] >= 3.5
        print("DP_COMPRESSED_TRACKS_OK")
        """
    )
    assert "DP_COMPRESSED_TRACKS_OK" in run_prog(prog)


@pytest.mark.dist
def test_compressed_pmean_local_close_to_exact():
    """compressed_pmean_local over ranks holding DIFFERENT shards: equals
    compressed psum / n exactly and the exact fp32 mean within the int8
    bound; exact_pmean_local is bitwise the stacked mean."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import (
            compressed_pmean_local, compressed_psum_local, exact_pmean_local,
            exact_pmean_stacked,
        )

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        key = jax.random.PRNGKey(1)

        def f(gs, key):
            return (compressed_pmean_local(gs, "data", key, bits=8),
                    compressed_psum_local(gs, "data", key, bits=8),
                    exact_pmean_local(gs, "data"))

        mean8, sum8, mean32 = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P()),
            out_specs=(P(), P(), P()), check_vma=False,
        ))(g, key)
        np.testing.assert_array_equal(np.asarray(mean8),
                                      np.asarray(sum8) / 8.0)
        exact = np.asarray(g).reshape(8, 8, 32).mean(0)
        np.testing.assert_array_equal(
            np.asarray(mean32),
            np.asarray(exact_pmean_stacked(jnp.asarray(g).reshape(8, 8, 32))),
        )
        err = np.abs(np.asarray(mean8) - exact).max()
        bound = 1.5 * np.abs(np.asarray(g)).max() / 127.0
        print("err", err, "bound", bound)
        assert err < bound
        print("PMEAN_OK")
        """
    )
    assert "PMEAN_OK" in run_prog(prog)
