"""Pallas flash-attention kernel vs the naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from tests.test_attention import naive_attention

jax.config.update("jax_platform_name", "cpu")

CASES = [
    # (t, s, h, kh, d, causal, window, bq, bk)
    (64, 64, 2, 2, 32, True, None, 32, 32),
    (64, 64, 4, 2, 16, True, None, 16, 16),   # GQA
    (96, 96, 2, 1, 16, True, None, 32, 32),   # ragged vs blocks
    (64, 64, 2, 2, 16, False, None, 32, 32),  # encoder
    (128, 128, 2, 2, 16, True, 32, 32, 32),   # sliding window
]


@pytest.mark.parametrize("t,s,h,kh,d,causal,window,bq,bk", CASES)
def test_flash_kernel_matches_naive(t, s, h, kh, d, causal, window, bq, bk):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, t, h, d))
    k = jax.random.normal(k2, (2, s, kh, d))
    v = jax.random.normal(k3, (2, s, kh, d))
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              q_block=bq, k_block=bk, interpret=True)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=2e-4)


def test_flash_kernel_bf16():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (1, 64, 2, 32), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 64, 2, 32), jnp.bfloat16)
    out = flash_attention_fwd(q, k, v, q_block=32, k_block=32, interpret=True)
    ref = naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=3e-2, rtol=3e-2
    )


def test_flash_kernel_matches_pure_jax_flash():
    """The kernel and the pure-JAX flash must agree (same algorithm, two
    execution strategies — VMEM-fused vs scan)."""
    from repro.models.layers import flash_attention

    key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 64, 4, 16))
    k = jax.random.normal(k2, (2, 64, 2, 16))
    v = jax.random.normal(k3, (2, 64, 2, 16))
    a = flash_attention_fwd(q, k, v, q_block=32, k_block=32, interpret=True)
    b = flash_attention(q, k, v, causal=True, q_block=32, k_block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5,
                               rtol=2e-4)
