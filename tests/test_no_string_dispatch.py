"""CI guard: no embedding-method string dispatch outside repro/methods/.

The registry redesign removed every ``spec.method == "lpt"`` /
``cfg.embedding_method in ("lpt", "alpt")`` chain from the trainers, the DP
wrapper, sharding, serving, dry-run, and checkpointing.  This test keeps it
that way, as a thin wrapper over the ``no-string-dispatch`` AST rule in
:mod:`repro.analysis.lint.rules` — the rule resolves real comparisons on the
syntax tree, so docstrings, comments, and string literals that merely
*mention* ``.method == "lpt"`` no longer trip it the way the old regex
walker did.
"""
from repro.analysis.lint import all_rules, run_lint


def test_no_method_string_dispatch_outside_registry():
    rule = next(r for r in all_rules() if r.name == "no-string-dispatch")
    findings = run_lint(rules=[rule])
    assert not findings, (
        "embedding-method string dispatch found — use the repro.methods "
        "registry (methods.get(name) + capability flags like "
        "is_integer_table / has_learned_step) instead:\n"
        + "\n".join(f.format() for f in findings)
    )
