"""CI guard: no embedding-method string dispatch outside repro/methods/.

The registry redesign removed every ``spec.method == "lpt"`` /
``cfg.embedding_method in ("lpt", "alpt")`` chain from the trainers, the DP
wrapper, sharding, serving, dry-run, and checkpointing.  This test keeps it
that way: any attribute-qualified comparison of ``.method`` /
``.embedding_method`` against string literals (equality or tuple membership)
in ``src/repro`` outside ``repro/methods/`` fails the build with a pointer to
the registry.

(Bare local parameters named ``method`` inside repro/core — QAT variant,
rounding mode — are algorithm knobs, not embedding-method dispatch, and are
not attribute-qualified, so they do not match.)
"""
import pathlib
import re

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

# `.method ==`, `.method !=`, `.method in (`, and the embedding_method twins,
# when compared against a string literal / tuple of literals.
DISPATCH = re.compile(
    r"\.(?:embedding_)?method\s*(?:[=!]=\s*[\"']|in\s*\(\s*[\"'])"
)


def test_no_method_string_dispatch_outside_registry():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if "methods" in path.relative_to(SRC).parts[:1]:
            continue  # the registry implementations may name themselves
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            if DISPATCH.search(line):
                offenders.append(f"{path.relative_to(SRC.parent.parent)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "embedding-method string dispatch found — use the repro.methods "
        "registry (methods.get(name) + capability flags like "
        "is_integer_table / has_learned_step) instead:\n"
        + "\n".join(offenders)
    )
