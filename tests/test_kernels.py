"""Per-kernel allclose tests vs the jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import ref
from repro.kernels.dequant_gather import dequant_gather
from repro.kernels.dequant_matmul import dequant_matmul
from repro.kernels.sr_round import sr_round, sr_round_seeded
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")

I = dict(interpret=True)


# ------------------------------------------------------------ dequant_gather


@pytest.mark.parametrize(
    "n,d,b,d_block",
    [
        (32, 16, 8, 16),
        (128, 128, 64, 128),
        (1000, 256, 37, 128),
        (64, 512, 128, 512),
    ],
)
def test_dequant_gather_matches_ref(n, d, b, d_block):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    codes = jax.random.randint(k1, (n, d), -128, 128, jnp.int8)
    step = jax.random.uniform(k2, (n,), minval=1e-3, maxval=0.1)
    ids = jax.random.randint(k3, (b,), 0, n, jnp.int32)
    out = dequant_gather(codes, step, ids, d_block=d_block, **I)
    expect = ref.dequant_gather_ref(codes, step, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_dequant_gather_repeated_ids():
    codes = jnp.arange(64, dtype=jnp.int8).reshape(4, 16)
    step = jnp.array([1.0, 0.5, 0.25, 2.0])
    ids = jnp.array([2, 2, 2, 0], jnp.int32)
    out = dequant_gather(codes, step, ids, d_block=16, **I)
    expect = ref.dequant_gather_ref(codes, step, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect))


# ------------------------------------------------------------ sr_round


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape", [(8, 16), (256, 512), (64, 1024), (512, 128)])
def test_sr_round_matches_ref_bit_exact(bits, shape):
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, shape) * 0.05
    step = jax.random.uniform(k2, (shape[0],), minval=1e-3, maxval=0.05)
    noise = jax.random.uniform(k3, shape)
    rb, cb = min(256, shape[0]), min(512, shape[1])
    out = sr_round(w, step, noise, bits, row_block=rb, col_block=cb, **I)
    expect = ref.sr_round_ref(w, step, noise, bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_sr_round_matches_core_quant():
    """Kernel == quant.quantize_codes (the semantics LPT depends on)."""
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (32, 64)) * 0.1
    step = jnp.full((32,), 0.01)
    noise = jax.random.uniform(jax.random.PRNGKey(3), (32, 64))
    out = sr_round(w, step, noise, 8, row_block=32, col_block=64, **I)
    expect = quant.quantize_codes(w, step, 8, "sr", noise)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_sr_round_seeded_lowers_and_is_on_lattice():
    """On-chip PRNG variant (production TPU path).

    The CPU TPU-interpreter stubs ``prng_random_bits`` to zeros, so the noise
    *distribution* can only be validated on real TPU hardware; here we verify
    the kernel lowers under TPU-semantics interpretation and that every output
    is one of the two adjacent lattice codes (the SR invariant that holds for
    ANY noise realization).
    """
    from jax.experimental.pallas import tpu as pltpu

    w = jnp.full((16, 128), 0.0155)
    step = jnp.full((16,), 0.01)
    out = sr_round_seeded(
        w, step, jnp.asarray(42), 8, row_block=16, col_block=128,
        interpret=pltpu.InterpretParams(),
    )
    vals = np.asarray(out)
    assert set(np.unique(vals)).issubset({1, 2})  # floor/ceil of 1.55 only


# ------------------------------------------------------------ dequant_matmul


@pytest.mark.parametrize(
    "m,n,k,bm,bn,bk",
    [
        (8, 16, 32, 8, 16, 32),
        (128, 128, 128, 128, 128, 128),
        (128, 256, 512, 128, 128, 128),
        (256, 128, 1024, 128, 128, 512),
    ],
)
@pytest.mark.parametrize("x_dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_matches_ref(m, n, k, bm, bn, bk, x_dtype):
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, k), x_dtype)
    codes = jax.random.randint(k2, (n, k), -128, 128, jnp.int8)
    step = jax.random.uniform(k3, (n,), minval=1e-3, maxval=0.02)
    out = dequant_matmul(x, codes, step, block_m=bm, block_n=bn, block_k=bk, **I)
    expect = ref.dequant_matmul_ref(x, codes, step)
    # Tolerances cover accumulation-order differences (blocked K vs one dot).
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect),
        rtol=2e-2 if x_dtype == jnp.bfloat16 else 1e-4,
        atol=2e-1 if x_dtype == jnp.bfloat16 else 1e-3,
    )


def test_dequant_matmul_equals_dequant_then_matmul():
    """Fusion must not change semantics vs materialize-then-matmul."""
    x = jax.random.normal(jax.random.PRNGKey(6), (16, 64))
    codes = jax.random.randint(jax.random.PRNGKey(7), (32, 64), -128, 128, jnp.int8)
    step = jnp.full((32,), 0.01)
    fused = dequant_matmul(x, codes, step, block_m=16, block_n=32, block_k=64, **I)
    table = quant.dequantize(codes, step)
    unfused = x @ table.T
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(unfused), rtol=1e-4, atol=1e-5
    )


# ------------------------------------------------------------ ops wrappers


def test_ops_fallback_on_unaligned():
    """Non-divisible shapes use the oracle — same numbers, counted fallback."""
    codes = jax.random.randint(jax.random.PRNGKey(8), (10, 7), -128, 128, jnp.int8)
    step = jnp.full((10,), 0.02)
    ids = jnp.array([0, 3, 9], jnp.int32)
    out = ops.dequant_gather(codes, step, ids)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.dequant_gather_ref(codes, step, ids))
    )


def test_fallback_stats_odd_dim_reported_aligned_not():
    """Satellite contract: an odd-dim table reports a shape fallback, an
    aligned one reports a kernel hit and NO fallback (never silent)."""
    ops.reset_fallback_stats()
    step = jnp.full((24,), 0.02)
    ids = jnp.array([1, 5], jnp.int32)
    # Odd dim (d=9 is not a sublane multiple) -> counted fallback.
    odd = jax.random.randint(jax.random.PRNGKey(20), (24, 9), -128, 128, jnp.int8)
    ops.dequant_gather(odd, step, ids)
    stats = ops.fallback_stats()
    assert stats["total_fallbacks"] == 1
    assert stats["fallbacks"][0]["op"] == "dequant_gather"
    assert "sublane" in stats["fallbacks"][0]["reason"]
    # Aligned dim -> kernel path, fallback count unchanged.
    aligned = jax.random.randint(jax.random.PRNGKey(21), (24, 16), -128, 128, jnp.int8)
    ops.dequant_gather(aligned, step, ids)
    stats = ops.fallback_stats()
    assert stats["total_fallbacks"] == 1
    assert stats["kernel_calls"].get("dequant_gather", 0) >= 1
    ops.reset_fallback_stats()
    assert ops.fallback_stats()["total_fallbacks"] == 0


def test_fallback_stats_sr_round_misaligned_rows():
    ops.reset_fallback_stats()
    w = jax.random.normal(jax.random.PRNGKey(22), (13, 16)) * 0.05
    step = jnp.full((13,), 0.01)
    noise = jax.random.uniform(jax.random.PRNGKey(23), (13, 16))
    out = ops.sr_round(w, step, noise, 8)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.sr_round_ref(w, step, noise, 8))
    )
    assert ops.fallback_stats()["total_fallbacks"] == 1
    ops.reset_fallback_stats()


def test_fallback_scope_reports_despite_prior_trace():
    """Satellite contract (PR 5): a scope sees every dispatch made while it
    is active — including shapes the process already traced and reset away,
    which the old reset-then-read dance in launch/serve.py under-reported."""
    ops.reset_fallback_stats()
    step = jnp.full((24,), 0.02)
    ids = jnp.array([1, 5], jnp.int32)
    odd = jax.random.randint(jax.random.PRNGKey(30), (24, 9), -128, 128, jnp.int8)
    ops.dequant_gather(odd, step, ids)  # compiled + counted globally
    assert ops.fallback_stats()["total_fallbacks"] == 1
    ops.reset_fallback_stats()  # the historical dance: reset...
    with ops.fallback_scope() as scope:
        ops.dequant_gather(odd, step, ids)  # ...same shapes, already compiled
    # ...and the scope still reports the fallback the dispatch actually hit.
    assert scope.stats()["total_fallbacks"] == 1
    assert scope.stats()["fallbacks"][0]["op"] == "dequant_gather"
    # Dispatches outside the scope are not attributed to it.
    ops.dequant_gather(odd, step, ids)
    assert scope.stats()["total_fallbacks"] == 1
    # Re-entering an existing scope accumulates (the Engine's usage).
    aligned = jax.random.randint(jax.random.PRNGKey(31), (24, 16), -128, 128,
                                 jnp.int8)
    with ops.fallback_scope(scope):
        ops.dequant_gather(aligned, step, ids)
    assert scope.stats()["kernel_calls"].get("dequant_gather", 0) == 1
    assert scope.stats()["total_fallbacks"] == 1
    ops.reset_fallback_stats()


def test_ops_jit_wrappers_run():
    w = jax.random.normal(jax.random.PRNGKey(9), (256, 512)) * 0.1
    step = jnp.full((256,), 0.01)
    noise = jax.random.uniform(jax.random.PRNGKey(10), (256, 512))
    codes = ops.sr_round(w, step, noise, 8)
    assert codes.dtype == jnp.int8
    x = jax.random.normal(jax.random.PRNGKey(11), (128, 512))
    y = ops.dequant_matmul(x, codes, step)
    assert y.shape == (128, 256)
    got = ops.dequant_gather(codes, step, jnp.arange(64, dtype=jnp.int32))
    assert got.shape == (64, 512)


# ------------------------------------------------------------ lpt_fused_update


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("shape,rb,cb", [((32, 64), 32, 64), ((256, 512), 256, 512),
                                         ((512, 1024), 256, 512)])
def test_lpt_fused_update_matches_ref(bits, shape, rb, cb):
    from repro.kernels.lpt_update import lpt_fused_update

    key = jax.random.PRNGKey(11)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    codes = jax.random.randint(k1, shape, -(2**(bits-1)), 2**(bits-1), jnp.int8)
    step = jax.random.uniform(k2, (shape[0],), minval=1e-3, maxval=0.05)
    grad = jax.random.normal(k3, shape) * 0.1
    noise = jax.random.uniform(k4, shape)
    out = lpt_fused_update(codes, step, grad, noise, 0.01, bits,
                           row_block=rb, col_block=cb, interpret=True)
    expect = ref.lpt_fused_update_ref(codes, step, grad, noise, 0.01, bits)
    # SR compares frac(w/Delta) against the noise draw; when they agree to
    # ~1 ULP the fused fma ordering may round the comparison the other way.
    # Allow <=0.01% knife-edge ties, never more than one lattice step apart.
    diff = np.asarray(out).astype(np.int32) - np.asarray(expect).astype(np.int32)
    assert np.abs(diff).max() <= 1
    assert (diff != 0).mean() < 1e-4


# ------------------------------------------------------- sparse_row_update


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("weight_decay", [0.0, 5e-8])
def test_sparse_row_update_matches_ref_bitwise(bits, weight_decay):
    """Fused gather+Adam+SR+scatter == the jnp oracle, bit for bit."""
    key = jax.random.PRNGKey(30)
    ks = jax.random.split(key, 6)
    n, d, k = 48, 16, 24
    codes = jax.random.randint(ks[0], (n, d), -(2**(bits-1)), 2**(bits-1), jnp.int8)
    step = jax.random.uniform(ks[1], (n,), minval=1e-3, maxval=0.05)
    mu = jax.random.normal(ks[2], (n, d)) * 0.01
    nu = jax.random.uniform(ks[3], (n, d)) * 1e-3
    uniq = jnp.asarray(
        np.random.RandomState(5).choice(n, k, replace=False), jnp.int32
    )
    g = jax.random.normal(ks[4], (k, d)) * 0.1
    noise = jax.random.uniform(ks[5], (k, d))
    t = 7.0
    c1, c2 = 1.0 - 0.9**t, 1.0 - 0.999**t
    on = ops.sparse_row_update(
        codes, step, mu, nu, uniq, g, noise, 0.01, c1, c2, bits,
        weight_decay=weight_decay, use_kernel=True,
    )
    off = ops.sparse_row_update(
        codes, step, mu, nu, uniq, g, noise, 0.01, c1, c2, bits,
        weight_decay=weight_decay, use_kernel=False,
    )
    # The table state (codes + Adam slots) is the bitwise contract.
    for a, b in zip(on[:3], off[:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # The auxiliary float rows may differ by one ULP where XLA's FMA
    # formation lands differently across the two traces; the train-step
    # parity suite (tests/test_methods_conformance.py) holds the end-to-end
    # state bitwise on the shipped configs.
    np.testing.assert_allclose(
        np.asarray(on[3]), np.asarray(off[3]), rtol=1e-6, atol=1e-9
    )


def test_sparse_row_update_untouched_rows_bit_identical():
    """The aliased scatter leaves rows outside ``uniq`` byte-for-byte alone."""
    key = jax.random.PRNGKey(31)
    ks = jax.random.split(key, 6)
    n, d, k = 32, 8, 4
    codes = jax.random.randint(ks[0], (n, d), -128, 128, jnp.int8)
    step = jax.random.uniform(ks[1], (n,), minval=1e-3, maxval=0.05)
    mu = jax.random.normal(ks[2], (n, d)) * 0.01
    nu = jax.random.uniform(ks[3], (n, d)) * 1e-3
    uniq = jnp.array([3, 9, 17, 31], jnp.int32)
    g = jax.random.normal(ks[4], (k, d)) * 0.1
    noise = jax.random.uniform(ks[5], (k, d))
    out_codes, out_mu, out_nu, _ = ops.sparse_row_update(
        codes, step, mu, nu, uniq, g, noise, 0.01, 0.1, 0.001, 8,
    )
    untouched = np.setdiff1d(np.arange(n), np.asarray(uniq))
    np.testing.assert_array_equal(
        np.asarray(out_codes)[untouched], np.asarray(codes)[untouched]
    )
    np.testing.assert_array_equal(
        np.asarray(out_mu)[untouched], np.asarray(mu)[untouched]
    )
    np.testing.assert_array_equal(
        np.asarray(out_nu)[untouched], np.asarray(nu)[untouched]
    )
    touched = np.asarray(uniq)
    assert (np.asarray(out_mu)[touched] != np.asarray(mu)[touched]).any()


def test_sparse_row_update_equals_core_sparse_apply():
    """Kernel path == lpt.sparse_apply's jnp path on every live row (the
    dedup sentinel parks in the scratch row, excluded)."""
    from repro.core import lpt as lpt_core

    key = jax.random.PRNGKey(32)
    k1, k2, k3 = jax.random.split(key, 3)
    n_live, d = 19, 16
    n_alloc = 24  # allocated past the id space: row 19 is the scratch row
    table = lpt_core.init_table(k1, n_alloc, d, 8, optimizer="adam")
    ids = jnp.array([[0, 5, 5], [18, 2, 5]], jnp.int32)
    g_rows = jax.random.normal(k2, ids.shape + (d,)) * 0.1
    kw = dict(lr=jnp.float32(0.01), bits=8, rounding="sr", noise_key=k3,
              optimizer="adam", weight_decay=5e-8, id_space=n_live)
    on = lpt_core.sparse_apply(table, ids, g_rows, use_kernels=True, **kw)
    off = lpt_core.sparse_apply(table, ids, g_rows, use_kernels=False, **kw)
    live = np.arange(n_live)
    np.testing.assert_array_equal(
        np.asarray(on.codes)[live], np.asarray(off.codes)[live]
    )
    np.testing.assert_array_equal(
        np.asarray(on.mu)[live], np.asarray(off.mu)[live]
    )
    np.testing.assert_array_equal(
        np.asarray(on.nu)[live], np.asarray(off.nu)[live]
    )
    np.testing.assert_array_equal(np.asarray(on.count), np.asarray(off.count))


def test_lpt_fused_update_with_new_step_matches_core():
    """Fused kernel == the unfused core path (dequant -> sgd -> SR requant),
    including ALPT's Delta' requantize (Algorithm 1 line 5)."""
    from repro.kernels.lpt_update import lpt_fused_update

    key = jax.random.PRNGKey(12)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    codes = jax.random.randint(k1, (64, 128), -128, 128, jnp.int8)
    step = jax.random.uniform(k2, (64,), minval=1e-3, maxval=0.02)
    new_step = step * jax.random.uniform(k5, (64,), minval=0.8, maxval=1.2)
    grad = jax.random.normal(k3, (64, 128)) * 0.05
    noise = jax.random.uniform(k4, (64, 128))
    out = lpt_fused_update(codes, step, grad, noise, 0.01, 8,
                           new_step=new_step, row_block=64, col_block=128,
                           interpret=True)
    w = quant.dequantize(codes, step) - 0.01 * grad
    expect = quant.quantize_codes(w, new_step, 8, "sr", noise)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


# ------------------------------------------------------- packed containers
#
# The packed-storage contract: a CodeStore at bits in {2, 4} keeps its codes
# packed through every fused op — packed bytes move HBM->VMEM, the unpack
# (and the scatter's re-pack) happen in VMEM — and the results are BITWISE
# equal to the raw int8 path, kernels on or off.


def _packed_fixture(bits, n=32, d=16, seed=40):
    from repro.core import codestore

    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    raw = jax.random.randint(
        ks[0], (n, d), -(2 ** (bits - 1)), 2 ** (bits - 1), jnp.int8
    )
    step = jax.random.uniform(ks[1], (n,), minval=1e-3, maxval=0.05)
    store = codestore.CodeStore.from_codes(raw, bits)
    assert store.packed and store.data.dtype == jnp.uint8
    return raw, store, step


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_packed_dequant_gather_bitwise(bits, use_kernel):
    raw, store, step = _packed_fixture(bits)
    ids = jnp.array([0, 5, 5, 31, 2, 17, 8, 30], jnp.int32)
    got = ops.dequant_gather(store, step, ids, use_kernel=use_kernel)
    expect = ops.dequant_gather(raw, step, ids, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_packed_lpt_update_bitwise(bits, use_kernel):
    raw, store, step = _packed_fixture(bits)
    ks = jax.random.split(jax.random.PRNGKey(41), 2)
    grad = jax.random.normal(ks[0], raw.shape) * 0.05
    noise = jax.random.uniform(ks[1], raw.shape)
    got = ops.lpt_update(
        store, step, grad, noise, 0.01, bits, use_kernel=use_kernel
    )
    expect = ops.lpt_update(
        raw, step, grad, noise, 0.01, bits, use_kernel=False
    )
    assert got.bits == bits and got.packed  # layout preserved on write-back
    np.testing.assert_array_equal(
        np.asarray(got.unpack()), np.asarray(expect)
    )


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_packed_sparse_row_update_bitwise(bits, use_kernel):
    raw, store, step = _packed_fixture(bits)
    n, d = raw.shape
    k = 8
    ks = jax.random.split(jax.random.PRNGKey(42), 4)
    mu = jax.random.normal(ks[0], (n, d)) * 0.01
    nu = jax.random.uniform(ks[1], (n, d)) * 1e-3
    uniq = jnp.asarray(
        np.random.RandomState(6).choice(n, k, replace=False), jnp.int32
    )
    g = jax.random.normal(ks[2], (k, d)) * 0.1
    noise = jax.random.uniform(ks[3], (k, d))
    t = 3.0
    c1, c2 = 1.0 - 0.9**t, 1.0 - 0.999**t
    got = ops.sparse_row_update(
        store, step, mu, nu, uniq, g, noise, 0.01, c1, c2, bits,
        use_kernel=use_kernel,
    )
    expect = ops.sparse_row_update(
        raw, step, mu, nu, uniq, g, noise, 0.01, c1, c2, bits,
        use_kernel=False,
    )
    assert got[0].bits == bits and got[0].packed
    np.testing.assert_array_equal(
        np.asarray(got[0].unpack()), np.asarray(expect[0])
    )
    for a, b in zip(got[1:3], expect[1:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_packed_dequant_matmul_bitwise(bits, use_kernel):
    raw, store, step = _packed_fixture(bits)
    x = jax.random.normal(jax.random.PRNGKey(43), (8, raw.shape[1]))
    got = ops.dequant_matmul(x, store, step, use_kernel=use_kernel)
    expect = ops.dequant_matmul(x, raw, step, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


@pytest.mark.parametrize("bits", [2, 4])
def test_packed_dispatch_counts_no_fallbacks(bits):
    """Packed dispatches land on the kernel path (counted under the same op
    names as unpacked — the 'never silent' contract) with zero fallbacks on
    aligned geometry."""
    raw, store, step = _packed_fixture(bits)
    ids = jnp.arange(16, dtype=jnp.int32)
    ops.reset_fallback_stats()
    ops.dequant_gather(store, step, ids)
    grad = jnp.zeros(raw.shape, jnp.float32)
    noise = jnp.full(raw.shape, 0.5)
    ops.lpt_update(store, step, grad, noise, 0.01, bits)
    stats = ops.fallback_stats()
    assert stats["total_fallbacks"] == 0, stats
    assert stats["kernel_calls"].get("dequant_gather", 0) >= 1
    assert stats["kernel_calls"].get("lpt_update", 0) >= 1
