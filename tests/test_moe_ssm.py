"""MoE dispatch invariants + Mamba2 SSD vs naive recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------------- MoE


def _setup_moe(e=4, k=2, d=16, f=32, cf=2.0, shared=0):
    cfg = moe_mod.MoEConfig(
        n_experts=e, top_k=k, d_model=d, d_ff=f, capacity_factor=cf,
        n_shared_experts=shared, shared_d_ff=f if shared else None,
    )
    params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_moe_output_shape_and_finite():
    cfg, params = _setup_moe()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_mod.moe_forward(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_moe_matches_dense_reference_at_high_capacity():
    """With capacity >= S*k (no drops), the buffer dispatch must equal the
    naive dense formulation sum_j gate_j * FFN_{e_j}(x)."""
    cfg, params = _setup_moe(cf=10.0)  # no drops
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
    y, _ = moe_mod.moe_forward(params, x, cfg)

    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        fe = h @ params["w_down"][e]
        w = ((idx == e) * gate).sum(-1)  # [B, S]
        y_ref += w[..., None] * fe
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5,
                               rtol=1e-4)


def test_moe_capacity_drops_fall_back_to_zero():
    """With capacity 1 slot/expert, overflow tokens contribute nothing (the
    residual stream passes them through in the transformer)."""
    cfg, params = _setup_moe(e=2, k=1, cf=0.01)
    assert moe_mod.capacity(cfg, 16) == 1
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16))
    y, _ = moe_mod.moe_forward(params, x, cfg)
    # At most e slots get expert output; the rest must be exactly zero.
    nz_tokens = (np.abs(np.asarray(y)[0]).sum(-1) > 1e-9).sum()
    assert nz_tokens <= 2


def test_moe_shared_experts_always_on():
    cfg, params = _setup_moe(shared=2, cf=10.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 16))
    y_with, _ = moe_mod.moe_forward(params, x, cfg)
    sh = params["shared"]
    hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
    shared_out = hs @ sh["w_down"]
    # Removing the shared contribution must equal the routed-only output.
    cfg0, _ = _setup_moe(shared=0, cf=10.0)
    routed, _ = moe_mod.moe_forward(
        {k: v for k, v in params.items() if k != "shared"}, x, cfg0
    )
    np.testing.assert_allclose(
        np.asarray(y_with), np.asarray(routed + shared_out), atol=1e-5,
        rtol=1e-4,
    )


# ------------------------------------------------------------------- SSD


def naive_ssm_recurrence(x, dt, A, B_, C_):
    """Token-by-token reference: S_t = exp(dt_t A) S_{t-1} + B_t (x) (x_t dt_t)."""
    b, t, h, p = x.shape
    n = B_.shape[-1]
    S = np.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        a = np.exp(np.asarray(dt[:, i]) * np.asarray(A))  # [b, h]
        xdt = np.asarray(x[:, i]) * np.asarray(dt[:, i])[..., None]  # [b,h,p]
        S = a[:, :, None, None] * S + np.einsum(
            "bn,bhp->bhpn", np.asarray(B_[:, i]), xdt
        )
        ys.append(np.einsum("bn,bhpn->bhp", np.asarray(C_[:, i]), S))
    return np.stack(ys, axis=1), S


@pytest.mark.parametrize("t,chunk", [(16, 4), (32, 8), (24, 24), (8, 8)])
def test_ssd_chunked_matches_naive_recurrence(t, chunk):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    b, h, p, n = 2, 3, 4, 8
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.random.uniform(ks[1], (b, t, h), minval=0.01, maxval=0.2)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, t, n)) * 0.5
    C_ = jax.random.normal(ks[0], (b, t, n)) * 0.5
    y, state = ssm_mod.ssd_chunked(x, dt, A, B_, C_, chunk)
    y_ref, state_ref = naive_ssm_recurrence(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), state_ref, atol=1e-4,
                               rtol=1e-3)


def test_ssd_state_carry_equals_full_sequence():
    """Processing [first half] then [second half with carried state] must equal
    one full pass — the prefill->decode contract."""
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 5)
    b, t, h, p, n = 1, 32, 2, 4, 8
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.random.uniform(ks[1], (b, t, h), minval=0.01, maxval=0.2)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, t, n)) * 0.5
    C_ = jax.random.normal(ks[4], (b, t, n)) * 0.5
    y_full, s_full = ssm_mod.ssd_chunked(x, dt, A, B_, C_, 8)
    y1, s1 = ssm_mod.ssd_chunked(x[:, :16], dt[:, :16], A, B_[:, :16],
                                 C_[:, :16], 8)
    y2, s2 = ssm_mod.ssd_chunked(x[:, 16:], dt[:, 16:], A, B_[:, 16:],
                                 C_[:, 16:], 8, ssm_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, 16:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)


# ------------------------------------------------------- LPT fuzz property


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 1000),
    bits=st.sampled_from([2, 4, 8]),
    lr=st.floats(1e-4, 1.0),
)
def test_lpt_codes_always_in_range_after_update(seed, bits, lr):
    """System invariant: no optimizer step may push codes out of the m-bit
    range (the int8 container must always decode to the claimed width)."""
    from repro.core import lpt, quant

    key = jax.random.PRNGKey(seed)
    t = lpt.init_table(key, 16, 8, bits, optimizer="adam")
    ids = jax.random.randint(key, (6,), 0, 16, jnp.int32)
    g = jax.random.normal(key, (6, 8)) * 10.0  # adversarially large grads
    t2 = lpt.sparse_apply(
        t, ids, g, lr=lr, bits=bits, rounding="sr", noise_key=key,
        optimizer="adam",
    )
    lo, hi = quant.code_bounds(bits)
    assert int(t2.codes.min()) >= lo
    assert int(t2.codes.max()) <= hi
