"""Fault injection + recovery (`repro.faults`): the PR-9 acceptance contract.

* **Every seam fires on schedule** — the ten catalogued injection sites
  (trainer.nonfinite, alpt.delta, codestore.corrupt, cold.fetch,
  cold.prefetch_loss, cache.admission, tiered.writeback, checkpoint.corrupt,
  kernels.force_fallback, train.preempt) each fire exactly on their
  FaultPlan steps and tick their typed counters.
* **Recoverable faults are bitwise-invisible** — cold-tier corruption /
  fetch failures / prefetch losses, refused cache admissions, write-back
  retries, forced kernel fallbacks, and an injected preemption+resume all
  produce outputs bit-identical to the fault-free run.
* **Skip-step semantics** — injected non-finite steps roll the state back
  (only step/rng advance) and the guard's skip count matches the injected
  NaN count exactly.
* **Deterministic retry** — backoff schedules are pure functions of
  (attempts, base, factor); exhaustion raises RetryError loudly.
* **Exact resume** — save at step k, restore in a fresh trainer, continue:
  losses and the exported final state are bitwise-equal to the
  uninterrupted run, for lpt / alpt / qr_alpt / mixed.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, methods
from repro.checkpoint.manager import CheckpointManager, CorruptCheckpointError
from repro.core import alpt, lpt
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.faults import FaultPlan, FaultSpec, RetryError, RetryStats
from repro.faults import recovery
from repro.kernels import ops
from repro.models.ctr import DCNConfig
from repro.serving.ctr import CTREngine, CTRRequest
from repro.storage.cold import ColdStore
from repro.storage.tiered import HotRowCache
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.chaos

CHAOS_DATA = CTRDatasetConfig(
    name="chaos", n_fields=4, cardinalities=(13, 29, 7, 53),
    teacher_rank=2, seed=0,
)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Plans are process-global; never let one test's chaos leak into another."""
    faults.uninstall()
    yield
    faults.uninstall()


def _spec_for(method, *, n, d=8, bits=8):
    kw = dict(method=method, n=n, d=d, bits=bits, init_scale=0.05)
    if method.startswith("qr"):
        kw["hash_compression"] = 4.0
    if method == "mixed":
        q, r = divmod(n, 4)
        kw["field_cards"] = (q, q, q, q + r)
        kw["field_bits"] = (8, 4, 8, 2)
    return methods.EmbeddingSpec(**kw)


def _trainer(method, *, guard=False, cache_rows=0, d=8):
    spec = _spec_for(method, n=CHAOS_DATA.n_features, d=d)
    return CTRTrainer(TrainerConfig(
        spec=spec, model="dcn",
        dcn=DCNConfig(n_fields=CHAOS_DATA.n_fields, emb_dim=d,
                      cross_depth=1, mlp_widths=(16,)),
        guard=guard, cache_rows=cache_rows,
    ))


def _run_steps(trainer, state, data, lo, hi, batch=32):
    losses = []
    for i in range(lo, hi):
        ids, labels = data.batch("train", i, batch)
        state, m = trainer.train_step(state, ids, labels)
        losses.append(float(m["loss"]))
    return state, losses


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _all_float_leaves_finite(tree) -> bool:
    for x in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(x)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
            return False
    return True


# ===================================================================== plan


def test_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(seed=7, specs=(
        FaultSpec(site="trainer.nonfinite", steps=(3, 7)),
        FaultSpec(site="cold.fetch", steps=(2,), params={"fails": 2}),
        FaultSpec(site="kernels.force_fallback", always=True),
    ))
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = FaultPlan.load(path)
    assert loaded == plan
    assert loaded.fires("trainer.nonfinite", 3)
    assert not loaded.fires("trainer.nonfinite", 4)
    assert loaded.fires("kernels.force_fallback", 12345)  # always
    assert loaded.lookup("cold.fetch").param("fails") == 2
    assert loaded.lookup("no.such.site") is None
    assert not loaded.fires("no.such.site", 0)


def test_plan_duplicate_sites_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan(specs=(
            FaultSpec(site="cold.fetch", steps=(1,)),
            FaultSpec(site="cold.fetch", steps=(2,)),
        ))


def test_step_mask_matches_host_schedule():
    spec = FaultSpec(site="trainer.nonfinite", steps=(1, 4))
    fire = faults.step_mask(spec)
    for step in range(6):
        assert bool(fire(jnp.int32(step))) == spec.fires(step)
    assert not bool(faults.step_mask(None)(jnp.int32(0)))
    assert bool(faults.step_mask(FaultSpec(site="x", always=True))(jnp.int32(9)))


# ==================================================================== retry


def test_backoff_schedule_deterministic():
    assert recovery.backoff_schedule(4, 0.002) == (0.002, 0.004, 0.008)
    assert recovery.backoff_schedule(1, 0.002) == ()
    # The cap bounds every term, so chaos runs can't sleep unboundedly.
    assert recovery.backoff_schedule(12, 0.5, max_s=1.0)[-1] == 1.0


def test_retry_succeeds_after_transients_with_recorded_backoff():
    stats = RetryStats()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise faults.TransientFault("injected")
        return "ok"

    sleeps: list[float] = []
    out = recovery.retry_with_backoff(
        flaky, op="t", attempts=4, base_s=0.002, stats=stats,
        sleep=sleeps.append,
    )
    assert out == "ok"
    assert calls["n"] == 3
    # The applied backoff is exactly the deterministic schedule prefix.
    assert tuple(sleeps) == recovery.backoff_schedule(4, 0.002)[:2]
    assert stats.calls == 1
    assert stats.retries == 2
    assert stats.failures == 0
    assert stats.backoff_s == sum(sleeps)


def test_retry_exhaustion_is_loud():
    stats = RetryStats()

    def doomed():
        raise faults.TransientFault("always")

    with pytest.raises(RetryError, match="failed after 3 attempts") as ei:
        recovery.retry_with_backoff(
            doomed, op="t", attempts=3, base_s=0.0, stats=stats,
            sleep=lambda s: None,
        )
    assert isinstance(ei.value.__cause__, faults.TransientFault)
    assert stats.failures == 1
    assert stats.retries == 2


def test_retry_real_bugs_propagate_immediately():
    stats = RetryStats()

    def bug():
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        recovery.retry_with_backoff(bug, op="t", attempts=5, stats=stats,
                                    sleep=lambda s: None)
    assert stats.retries == 0


# =================================================================== guards


def test_guard_skip_count_matches_injected_nan_count():
    fired_steps = (1, 3)
    faults.install(FaultPlan(specs=(
        FaultSpec(site="trainer.nonfinite", steps=fired_steps),
    )))
    trainer = _trainer("alpt", guard=True)  # seams bind at construction
    data = CTRSynthetic(CHAOS_DATA)
    state = trainer.init_state()
    for i in range(5):
        ids, labels = data.batch("train", i, 32)
        before = state
        state, _ = trainer.train_step(state, ids, labels)
        if int(before.step) in fired_steps:
            # Skip-step semantics: rollback everything but the step/rng clock.
            _assert_trees_equal(state.dense_params, before.dense_params)
            _assert_trees_equal(state.emb_state, before.emb_state)
        assert int(state.step) == int(before.step) + 1
    assert trainer.guard_stats.skipped == len(fired_steps)
    assert trainer.guard_stats.nonfinite_fired == len(fired_steps)
    assert _all_float_leaves_finite(state.dense_params)


def test_alpt_delta_blowup_recovered_by_skip_step():
    faults.install(FaultPlan(specs=(
        FaultSpec(site="alpt.delta", steps=(2,)),  # default scale: inf
    )))
    trainer = _trainer("alpt", guard=True)
    data = CTRSynthetic(CHAOS_DATA)
    state, _ = _run_steps(trainer, trainer.init_state(), data, 0, 4)
    assert trainer.guard_stats.delta_fired == 1
    assert trainer.guard_stats.skipped == 1
    assert _all_float_leaves_finite(state.emb_state)
    assert _all_float_leaves_finite(state.dense_params)


def test_alpt_step_clamp_bounds_finite_blowup():
    clamp = 0.005
    cfg = alpt.ALPTConfig(bits=8, optimizer="sgd", step_lr=1e-3,
                          step_clamp=clamp)
    table = lpt.init_table(jax.random.PRNGKey(0), 16, 8, 8,
                           step_size=0.01, optimizer="sgd")
    ids = jnp.array([1, 2, 3])
    c = jax.random.normal(jax.random.PRNGKey(1), (3, 8))
    new_table, _, aux = alpt.alpt_step(
        table, ids, lambda rows: jnp.sum(rows * c), cfg=cfg, lr=0.05,
        noise_key=jax.random.PRNGKey(2),
    )
    # Initial Delta (0.01) sits above the clamp, so every touched row clamps.
    assert int(aux["delta_clamped"]) == 3
    assert float(jnp.max(new_table.step[ids])) <= clamp + 1e-12


# ================================================================ cold tier


def _make_cold(codes, step):
    return ColdStore(codes, step, cache_rows=8, name="chaos")


def test_cold_tier_seams_are_bitwise_invisible():
    rng = np.random.RandomState(0)
    codes = jnp.asarray(rng.randint(-127, 128, size=(64, 16)), jnp.int8)
    step = jnp.asarray(rng.uniform(0.01, 0.1, size=(64,)), jnp.float32)
    waves = [rng.randint(0, 64, size=8) for _ in range(3)]

    ref = _make_cold(codes, step)
    ref_out = []
    for ids in waves:
        ref.stage(ids)
        ref_out.append(np.asarray(ref.rows(ids)))

    faults.install(FaultPlan(specs=(
        FaultSpec(site="codestore.corrupt", steps=(0,)),
        FaultSpec(site="cold.fetch", steps=(1,), params={"fails": 2}),
        FaultSpec(site="cold.prefetch_loss", steps=(2,)),
    )))
    chaos = _make_cold(codes, step)
    for ids, expect in zip(waves, ref_out):
        chaos.stage(ids)
        np.testing.assert_array_equal(np.asarray(chaos.rows(ids)), expect)

    assert chaos.corruption_detected == 1  # wave 0: staged bytes flipped
    assert chaos.retry_stats.retries == 2  # wave 1: two transient failures
    assert chaos.prefetch_dropped == 1  # wave 2: staged copy vanished
    assert chaos.retry_stats.failures == 0
    # 3 staged fetches + 2 demand re-fetches (corruption, prefetch loss).
    assert chaos.retry_stats.calls == 5
    assert chaos.demand_puts == 2


def test_cold_fetch_exhaustion_raises_retry_error():
    rng = np.random.RandomState(1)
    codes = jnp.asarray(rng.randint(-127, 128, size=(16, 8)), jnp.int8)
    step = jnp.ones((16,), jnp.float32)
    faults.install(FaultPlan(specs=(
        FaultSpec(site="cold.fetch", steps=(0,),
                  params={"fails": 5, "attempts": 2}),
    )))
    store = _make_cold(codes, step)
    with pytest.raises(RetryError, match="cold.fetch"):
        store.stage(np.arange(4))
    assert store.retry_stats.failures == 1


# ============================================================ tiered storage


def test_cache_admission_refusal_keeps_training_bitwise():
    data = CTRSynthetic(CHAOS_DATA)
    ref_trainer = _trainer("alpt")
    ref_state, ref_losses = _run_steps(
        ref_trainer, ref_trainer.init_state(), data, 0, 4
    )

    # Refuse EVERY admission: the cache stays empty, every read/write serves
    # off the backing tier — degraded, counted, and bitwise-equal.
    faults.install(FaultPlan(specs=(
        FaultSpec(site="cache.admission", always=True),
    )))
    deg_trainer = _trainer("alpt", cache_rows=4)
    deg_state, deg_losses = _run_steps(
        deg_trainer, deg_trainer.init_state(), data, 0, 4
    )

    assert deg_losses == ref_losses
    _assert_trees_equal(deg_trainer.export_state(deg_state), ref_state)
    stats = deg_trainer.cache_stats()
    assert sum(s["admission_oom"] for s in stats) == 4  # one per step
    assert all(s["rows_cached"] == 0 for s in stats)


def _dirty_cache_setup(codes):
    """A 4-slot cache over an 8-row backing with rows 1, 2 cached and dirty."""
    cache = HotRowCache(4, 8, name="wb")
    tiered = cache.apply(cache.wrap(codes), cache.observe(np.array([1, 2])))
    new_rows = jnp.asarray([[7, 7, 7, 7], [-7, -7, -7, -7]], jnp.int8)
    tiered = tiered.set_rows(jnp.array([1, 2]), new_rows)
    cache.observe(np.array([1, 2]), write=True)  # mark the written rows dirty
    return cache, tiered


def test_writeback_retry_is_bitwise_and_counted():
    codes = jnp.asarray(
        np.random.RandomState(2).randint(-5, 6, (8, 4)), jnp.int8
    )
    ref_cache, ref_tiered = _dirty_cache_setup(codes)
    ref_backing = np.asarray(ref_cache.flush(ref_tiered).backing)

    faults.install(FaultPlan(specs=(
        FaultSpec(site="tiered.writeback", steps=(0,), params={"fails": 2}),
    )))
    cache, tiered = _dirty_cache_setup(codes)
    flushed = cache.flush(tiered)
    np.testing.assert_array_equal(np.asarray(flushed.backing), ref_backing)
    assert cache.retry_stats.retries == 2
    assert cache.retry_stats.failures == 0
    assert not cache.dirty.any()
    assert cache.stats()["writeback_retries"] == 2


def test_writeback_exhaustion_keeps_rows_flagged():
    codes = jnp.zeros((8, 4), jnp.int8)
    faults.install(FaultPlan(specs=(
        FaultSpec(site="tiered.writeback", steps=(0,),
                  params={"fails": 5, "attempts": 2}),
    )))
    cache, tiered = _dirty_cache_setup(codes)
    with pytest.raises(RetryError, match="tiered.writeback"):
        cache.flush(tiered)
    assert cache.retry_stats.failures == 1
    assert cache.dirty.any()  # nothing lost: rows still flagged for retry


# =============================================================== checkpoints


def test_checkpoint_corruption_falls_back_to_last_good(tmp_path):
    tree1 = {"s": jnp.int32(1), "w": jnp.arange(6.0).reshape(2, 3)}
    tree2 = {"s": jnp.int32(2), "w": jnp.arange(6.0).reshape(2, 3) * 2}
    mgr = CheckpointManager(tmp_path, keep=5, save_every=1)
    assert mgr.maybe_save(tree1, 1)
    assert mgr.maybe_save(tree2, 2)

    faults.corrupt_checkpoint_leaf(tmp_path, 2)
    restored, manifest = mgr.restore(tree1)
    assert manifest["step"] == 1
    assert mgr.corrupt_steps == [2]
    _assert_trees_equal(restored, tree1)

    # An explicitly requested corrupted step is refused, never half-loaded.
    with pytest.raises(CorruptCheckpointError):
        mgr.restore(tree1, step=2)

    faults.corrupt_checkpoint_leaf(tmp_path, 1)
    fresh = CheckpointManager(tmp_path, keep=5, save_every=1)
    with pytest.raises(CorruptCheckpointError, match="failed verification"):
        fresh.restore(tree1)
    assert fresh.corrupt_steps == [2, 1]


@pytest.mark.parametrize("method", ["lpt", "alpt", "qr_alpt", "mixed"])
def test_exact_resume_parity(method, tmp_path):
    data = CTRSynthetic(CHAOS_DATA)
    ref_trainer = _trainer(method)
    ref_state, ref_losses = _run_steps(
        ref_trainer, ref_trainer.init_state(), data, 0, 6
    )

    # First life: train through a hot-row cache, checkpoint the exported
    # (cache-off-equivalent) state at step 3.
    tr1 = _trainer(method, cache_rows=4)
    s1, losses1 = _run_steps(tr1, tr1.init_state(), data, 0, 3)
    mgr = CheckpointManager(tmp_path, keep=2, save_every=100)
    assert mgr.maybe_save(tr1.export_state(s1), 3, force=True)

    # Second life: a fresh trainer restores and continues.
    tr2 = _trainer(method, cache_rows=4)
    template = tr2.export_state(tr2.init_state())
    restored, manifest = CheckpointManager(
        tmp_path, keep=2, save_every=100
    ).restore(template)
    s2 = tr2.import_state(restored)
    s2, losses2 = _run_steps(tr2, s2, data, manifest["step"], 6)

    assert losses1 + losses2 == ref_losses  # bitwise float equality
    _assert_trees_equal(tr2.export_state(s2), ref_trainer.export_state(ref_state))


# ================================================================== serving


def test_degraded_serving_bitwise_equal_to_cache_off():
    data = CTRSynthetic(CHAOS_DATA)
    trainer = _trainer("alpt")
    state, _ = _run_steps(trainer, trainer.init_state(), data, 0, 2)
    req_ids, _ = data.batch("test", 0, 16)

    def score(engine):
        rids = [engine.submit(CTRRequest(ids=row)) for row in req_ids]
        done = engine.run()
        return [done[r]["prob"] for r in rids]

    ref_engine = CTREngine.from_state(state, trainer.cfg, batch=8)
    ref_probs = score(ref_engine)

    faults.install(FaultPlan(specs=(
        FaultSpec(site="cache.admission", always=True),
    )))
    deg_engine = CTREngine.from_state(
        state, trainer.cfg, batch=8, cache_rows=4
    )
    assert score(deg_engine) == ref_probs  # bitwise float equality
    m = deg_engine.metrics()
    assert m["served_degraded"] == m["steps"] > 0
    assert m["retry_failures"] == 0
    health = deg_engine.health()
    # Recovered degradation keeps the engine READY — outputs stay correct.
    assert health["ready"]
    assert health["served_degraded"] == m["served_degraded"]


# ================================================================== kernels


def test_kernels_force_fallback_bitwise_and_counted():
    rng = np.random.RandomState(3)
    codes = jnp.asarray(rng.randint(-127, 128, size=(16, 8)), jnp.int8)
    step = jnp.asarray(rng.uniform(0.01, 0.1, size=(16,)), jnp.float32)
    ids = jnp.array([0, 3, 3, 9, 15])
    ref = np.asarray(ops.dequant_gather(codes, step, ids, use_kernel=False))

    faults.install(FaultPlan(specs=(
        FaultSpec(site="kernels.force_fallback", always=True),
    )))
    scope = ops.FallbackScope()
    with ops.fallback_scope(scope):
        forced = np.asarray(ops.dequant_gather(codes, step, ids))
    np.testing.assert_array_equal(forced, ref)
    reasons = {fb["reason"] for fb in scope.stats()["fallbacks"]
               if fb["op"] == "dequant_gather"}
    assert reasons == {"fault-injected"}

    # The 'ops' param narrows the seam: other ops are untouched.
    faults.install(FaultPlan(specs=(
        FaultSpec(site="kernels.force_fallback", always=True,
                  params={"ops": ["sr_round"]}),
    )))
    scope2 = ops.FallbackScope()
    with ops.fallback_scope(scope2):
        np.testing.assert_array_equal(
            np.asarray(ops.dequant_gather(codes, step, ids)), ref
        )
    assert not any(fb["reason"] == "fault-injected"
                   for fb in scope2.stats()["fallbacks"])


# =============================================================== preemption


def test_injected_preemption_resumes_bitwise(tmp_path, capsys):
    from repro.launch import train as train_cli

    plan_path = tmp_path / "plan.json"
    FaultPlan(specs=(FaultSpec(site="train.preempt", steps=(2,)),)).save(
        plan_path
    )
    base = ["--arch", "ctr", "--steps", "4", "--batch", "8",
            "--ckpt-every", "1", "--log-every", "100", "--no-kernels"]

    def done_summary():
        out = capsys.readouterr().out
        return json.loads(out.rsplit("[train] done:", 1)[1].strip().splitlines()[0])

    # Preempted run: exits 75 with a forced checkpoint at the preempt step.
    rc = train_cli.main(base + ["--ckpt-dir", str(tmp_path / "ck"),
                                "--fault-plan", str(plan_path)])
    assert rc == 75
    capsys.readouterr()
    faults.uninstall()  # the CLI installs the plan process-globally

    # Requeue: resumes from the checkpoint and finishes the remaining steps.
    rc = train_cli.main(base + ["--ckpt-dir", str(tmp_path / "ck")])
    assert rc == 0
    resumed = done_summary()
    assert resumed["steps"] == 2  # steps 2..3 only

    # Uninterrupted reference run.
    rc = train_cli.main(base + ["--ckpt-dir", str(tmp_path / "ck-ref")])
    assert rc == 0
    ref = done_summary()
    assert ref["steps"] == 4
    assert resumed["final_loss"] == ref["final_loss"]  # bitwise equality
