"""Serving loop: continuous batcher correctness (greedy decode == reference)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import ContinuousBatcher, Request
from repro.models import transformer as tfm
from repro.training import lm_trainer

jax.config.update("jax_platform_name", "cpu")


def test_batcher_greedy_matches_manual_decode():
    cfg = configs.smoke_config("smollm-135m")
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    table_fp = lm_trainer.table_fp_of(state, cfg)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 12).astype(np.int32)

    # Manual greedy reference.
    logits, cache = tfm.prefill(
        state.params, table_fp, jnp.asarray(prompt)[None], cfg, max_len=20
    )
    want = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want.append(int(tok[0]))
    for i in range(3):
        logits, cache = tfm.decode_step(
            state.params, table_fp, tok, cache, jnp.asarray(12 + i, jnp.int32),
            cfg,
        )
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want.append(int(tok[0]))

    srv = ContinuousBatcher(state.params, state.table, cfg, batch=1, max_len=20)
    srv.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = srv.run()
    assert done[0] == want


def test_batcher_multiple_waves_complete():
    cfg = configs.smoke_config("qwen3-1.7b")
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(1), cfg, tcfg)
    srv = ContinuousBatcher(state.params, state.table, cfg, batch=2,
                            max_len=24)
    rng = np.random.RandomState(2)
    for rid in range(5):  # 5 requests through batch-2 slots -> 3 waves
        srv.submit(Request(
            rid=rid, prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
            max_new=3,
        ))
    done = srv.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in done.values())
    assert all(0 <= t < cfg.vocab_size for v in done.values() for t in v)
