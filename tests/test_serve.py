"""`repro.serving` Engine: int8-resident parity, slot-refill determinism,
serving checkpoint restore.

The PR-5 acceptance contract:

* LM decode and CTR scoring run through the same Engine API, and for every
  integer-table method the outputs are **bitwise** equal to the
  pre-redesign fp-exported path (prefill/decode and rows-scoring against the
  materialized ``method.serving_table`` export);
* the Engine never materializes an fp32 table for integer-table methods —
  resident embedding bytes == int8 code bytes + scale vectors;
* slot-refill determinism: the same requests produce the same per-request
  tokens/scores whatever the arrival order or slot assignment.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, methods
from repro.checkpoint import manager as ckpt
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models import transformer as tfm
from repro.models.ctr import DCNConfig
from repro.serving import table as serving_tbl
from repro.serving.ctr import CTREngine, CTRRequest
from repro.serving.lm import LMEngine, LMRequest
from repro.training import lm_trainer
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.serve

INT_METHODS = ["lpt", "alpt", "qr_lpt", "qr_alpt"]


# ----------------------------------------------------------------------- LM


def _lm_fixture(arch="smollm-135m", method=None, seed=0):
    cfg = configs.smoke_config(arch)
    if method is not None:
        cfg = dataclasses.replace(cfg, embedding_method=method)
    tcfg = lm_trainer.LMTrainerConfig()
    state = lm_trainer.init_state(jax.random.PRNGKey(seed), cfg, tcfg)
    return cfg, tcfg, state


def _float_lm_engine(state, cfg, tcfg, *, batch, max_len):
    """The pre-redesign path as an Engine: fp-exported table resident."""
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    method = methods.get(spec.method)
    table = serving_tbl.FloatTable(method.serving_table(state.table, spec))
    return LMEngine(state.params, table, cfg, spec, batch=batch,
                    max_len=max_len)


def test_lm_engine_matches_manual_decode():
    """Engine greedy tokens == the raw prefill/decode_step loop over the
    fp-exported table (the pre-redesign serving arithmetic, untouched)."""
    cfg, tcfg, state = _lm_fixture()
    table_fp = lm_trainer.table_fp_of(state, cfg)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 12).astype(np.int32)

    logits, cache = tfm.prefill(
        state.params, table_fp, jnp.asarray(prompt)[None], cfg, max_len=20
    )
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    want = [int(tok[0])]
    for i in range(3):
        logits, cache = tfm.decode_step(
            state.params, table_fp, tok, cache, jnp.asarray(12 + i, jnp.int32),
            cfg,
        )
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want.append(int(tok[0]))

    engine = LMEngine.from_state(state, cfg, tcfg, batch=1, max_len=20)
    rid = engine.submit(LMRequest(prompt=prompt, max_new=4))
    done = engine.run()
    assert done[rid] == want
    assert engine.int8_resident


@pytest.mark.parametrize("method", INT_METHODS)
def test_lm_engine_int8_resident_bitwise_vs_fp_export(method):
    """int8-resident Engine == fp-export-resident Engine, token for token,
    while holding codes+scales instead of an fp32 table."""
    cfg, tcfg, state = _lm_fixture(method=method)
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    rng = np.random.RandomState(2)
    reqs = [
        LMRequest(rid=i,
                  prompt=rng.randint(0, cfg.vocab_size, 10).astype(np.int32),
                  max_new=3)
        for i in range(3)
    ]

    quant_eng = LMEngine.from_state(state, cfg, tcfg, batch=2, max_len=16)
    float_eng = _float_lm_engine(state, cfg, tcfg, batch=2, max_len=16)
    for r in reqs:
        quant_eng.submit(r)
        float_eng.submit(r)
    got, want = quant_eng.run(), float_eng.run()
    assert got == want

    assert quant_eng.int8_resident and not float_eng.int8_resident
    m = quant_eng.metrics()
    assert m["resident_embedding_bytes"] == (
        m["embedding_code_bytes"] + m["embedding_scale_bytes"]
    )
    fp32 = cfg.vocab_size * cfg.d_model * 4
    assert m["resident_embedding_bytes"] < fp32
    assert float_eng.metrics()["resident_embedding_bytes"] == fp32
    assert methods.get(spec.method).is_integer_table


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-370m"])
def test_lm_slot_refill_determinism(arch):
    """Same requests, any arrival order -> same per-request tokens.

    Mixed prompt lengths and generation budgets force slots to free and
    refill at staggered times, so the orders exercise genuinely different
    slot assignments (and, for mamba2, the exact-length SSM prefill)."""
    cfg, tcfg, state = _lm_fixture(arch=arch)
    rng = np.random.RandomState(3)
    reqs = [
        LMRequest(rid=i,
                  prompt=rng.randint(0, cfg.vocab_size, n).astype(np.int32),
                  max_new=g)
        for i, (n, g) in enumerate([(12, 5), (8, 2), (10, 4), (8, 1), (12, 3)])
    ]
    results = []
    for order in [reqs, reqs[::-1], reqs[2:] + reqs[:2]]:
        engine = LMEngine.from_state(state, cfg, tcfg, batch=2, max_len=20)
        for r in order:
            engine.submit(r)
        results.append(engine.run())
    assert results[0] == results[1] == results[2]
    assert sorted(results[0]) == [0, 1, 2, 3, 4]
    for r in reqs:
        assert len(results[0][r.rid]) == r.max_new


def test_lm_engine_rejects_oversized_request():
    cfg, tcfg, state = _lm_fixture()
    engine = LMEngine.from_state(state, cfg, tcfg, batch=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(LMRequest(
            prompt=np.zeros(12, np.int32), max_new=16,
        ))
    # Zero generation budget: finished with an empty token list, no slot used.
    rid = engine.submit(LMRequest(prompt=np.zeros(4, np.int32), max_new=0))
    assert engine.run()[rid] == []


def test_prefill_lens_right_padded_matches_exact():
    """`tfm.prefill(lens=)` (the future bucketed-prefill path): a right-padded
    row's last-real logits and its decode continuation match the exact-length
    batch-1 prefill — causal attention masks the padding exactly (to ~1 ulp:
    the padded shape changes XLA reduction order, see the prefill docstring;
    bitwise per-request determinism is why the Engine prefills exact-length).
    """
    cfg, tcfg, state = _lm_fixture()  # attention-only stack
    table_fp = lm_trainer.table_fp_of(state, cfg)
    rng = np.random.RandomState(7)
    p_short = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
    p_long = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)

    padded = np.zeros((2, 8), np.int32)
    padded[0, :5] = p_short
    padded[1] = p_long
    lens = jnp.asarray([5, 8], jnp.int32)
    logits_pad, cache_pad = tfm.prefill(
        state.params, table_fp, jnp.asarray(padded), cfg, max_len=16,
        lens=lens,
    )

    logits_a, cache_a = tfm.prefill(
        state.params, table_fp, jnp.asarray(p_short)[None], cfg, max_len=16
    )
    logits_b, _ = tfm.prefill(
        state.params, table_fp, jnp.asarray(p_long)[None], cfg, max_len=16
    )
    np.testing.assert_allclose(
        np.asarray(logits_pad[0]), np.asarray(logits_a[0]), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(logits_pad[1]), np.asarray(logits_b[0]), rtol=1e-5, atol=1e-5
    )

    # Decode continuation off the padded cache with per-slot cache_len: the
    # short row masks its pad tail and matches the exact-length decode.
    tok = jnp.argmax(logits_pad, -1).astype(jnp.int32)
    dec_pad, _ = tfm.decode_step(
        state.params, table_fp, tok, cache_pad, lens, cfg
    )
    dec_a, _ = tfm.decode_step(
        state.params, table_fp, tok[:1], cache_a, jnp.asarray(5, jnp.int32), cfg
    )
    np.testing.assert_allclose(
        np.asarray(dec_pad[0]), np.asarray(dec_a[0]), rtol=1e-5, atol=1e-5
    )


# ----------------------------------------------------------------------- CTR


CTR_DATA = CTRDatasetConfig(
    name="serve-test", n_fields=4, cardinalities=(23, 37, 11, 53),
    teacher_rank=3, seed=11,
)


def _ctr_fixture(method, steps=2):
    data = CTRSynthetic(CTR_DATA)
    spec = methods.EmbeddingSpec(
        method=method, n=CTR_DATA.n_features, d=8, bits=8, init_scale=0.05,
    )
    dcn = DCNConfig(n_fields=4, emb_dim=8, cross_depth=1, mlp_widths=(16,))
    trainer = CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn))
    state = trainer.init_state()
    for i in range(steps):
        ids, labels = data.batch("train", i, 16)
        state, _ = trainer.train_step(state, ids, labels)
    return trainer, state, data, spec


def _float_ctr_engine(trainer, state, spec, *, batch):
    method = methods.get(spec.method)
    table = serving_tbl.FloatTable(
        method.serving_table(state.emb_state, spec)
    )
    return CTREngine(state.dense_params, table, trainer.model_cfg, spec,
                     batch=batch, model=trainer.cfg.model)


@pytest.mark.parametrize("method", INT_METHODS)
def test_ctr_engine_int8_resident_bitwise_vs_fp_export(method):
    """CTR scoring: int8-resident Engine == fp-export-resident Engine,
    bit for bit on logits and probabilities."""
    trainer, state, data, spec = _ctr_fixture(method)
    quant_eng = CTREngine.from_state(state, trainer.cfg, batch=4)
    float_eng = _float_ctr_engine(trainer, state, spec, batch=4)
    ids, _ = data.batch("test", 0, 10)
    for i, row in enumerate(ids):
        quant_eng.submit(CTRRequest(rid=i, ids=row))
        float_eng.submit(CTRRequest(rid=i, ids=row))
    got, want = quant_eng.run(), float_eng.run()
    assert got == want  # dict of floats: bitwise (same f64 repr) per request

    assert quant_eng.int8_resident and not float_eng.int8_resident
    m = quant_eng.metrics()
    assert m["resident_embedding_bytes"] == (
        m["embedding_code_bytes"] + m["embedding_scale_bytes"]
    )
    assert m["resident_embedding_bytes"] < CTR_DATA.n_features * 8 * 4


def test_ctr_engine_arrival_order_determinism():
    """Same requests, any arrival order / batch packing -> same scores."""
    trainer, state, data, spec = _ctr_fixture("alpt")
    ids, _ = data.batch("test", 0, 9)
    results = []
    for order, batch in [(range(9), 4), (range(8, -1, -1), 4),
                         (range(9), 3)]:
        engine = CTREngine.from_state(state, trainer.cfg, batch=batch)
        for i in order:
            engine.submit(CTRRequest(rid=i, ids=ids[i]))
        results.append(engine.run())
    assert results[0] == results[1] == results[2]


def test_ctr_engine_rejects_bad_shape():
    trainer, state, _, _ = _ctr_fixture("lpt", steps=0)
    engine = CTREngine.from_state(state, trainer.cfg, batch=2)
    with pytest.raises(ValueError, match="shape"):
        engine.submit(CTRRequest(ids=np.zeros(7, np.int32)))


# ---------------------------------------------------------------- checkpoint


def test_lm_engine_from_serving_checkpoint(tmp_path):
    """Serving restore: int8 codes come off disk as int8, straight into
    residency; the restored Engine is bitwise-identical to the live one."""
    cfg, tcfg, state = _lm_fixture()
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    ckpt.save_serving_checkpoint(
        tmp_path, step=7, params=state.params, table=state.table, spec=spec,
    )

    # The artifact holds inference state only: codes + scales (+ params),
    # never the row-Adam moments the training table carries.
    import json

    manifest = json.loads(
        (tmp_path / "step_000000007" / "manifest.json").read_text()
    )
    table_leaves = [e for e in manifest["leaves"] if "table" in e["path"]]
    assert len(table_leaves) == 2  # codes + step
    assert sorted(e["dtype"] for e in table_leaves) == ["float32", "int8"]

    engine = LMEngine.from_checkpoint(tmp_path, cfg, tcfg, batch=1, max_len=16)
    assert engine.int8_resident
    assert engine.table.codes.dtype == jnp.int8

    live = LMEngine.from_state(state, cfg, tcfg, batch=1, max_len=16)
    prompt = np.random.RandomState(5).randint(0, cfg.vocab_size, 8).astype(np.int32)
    rid_a = engine.submit(LMRequest(prompt=prompt, max_new=3))
    rid_b = live.submit(LMRequest(prompt=prompt, max_new=3))
    assert engine.run()[rid_a] == live.run()[rid_b]


def test_serving_restore_refuses_method_mismatch(tmp_path):
    cfg, tcfg, state = _lm_fixture()
    spec = lm_trainer.embedding_spec_of(cfg, tcfg)
    ckpt.save_serving_checkpoint(
        tmp_path, step=1, params=state.params, table=state.table, spec=spec,
    )
    other = dataclasses.replace(spec, method="lpt")
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore_serving_checkpoint(tmp_path, other, params_template=None)
