"""Unit + property tests for repro.core.quant (paper §2.1, Eqs. 1-4, 6-7)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


def test_code_bounds():
    assert quant.code_bounds(8) == (-128, 127)
    assert quant.code_bounds(4) == (-8, 7)
    assert quant.code_bounds(2) == (-2, 1)
    with pytest.raises(ValueError):
        quant.code_bounds(1)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_codes_in_range(bits):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 16)) * 10.0  # force clipping
    step = jnp.full((64,), 0.01)
    noise = quant.sr_noise(jax.random.PRNGKey(1), w.shape)
    for rounding, nz in [("dr", None), ("sr", noise)]:
        codes = quant.quantize_codes(w, step, bits, rounding, nz)
        n, p = quant.code_bounds(bits)
        assert codes.dtype == jnp.int8
        assert int(codes.min()) >= n and int(codes.max()) <= p


def test_dr_rounding_half_up():
    # Eq. 3: frac < 0.5 -> floor, frac >= 0.5 -> floor + 1.
    x = jnp.array([0.4, 0.5, 0.6, -0.4, -0.5, -0.6, 2.5])
    out = quant.round_deterministic(x)
    np.testing.assert_array_equal(np.asarray(out), [0.0, 1.0, 1.0, 0.0, 0.0, -1.0, 3.0])


def test_dr_roundtrip_error_bound():
    """DR quantization error <= Delta/2 inside the clip range."""
    key = jax.random.PRNGKey(2)
    step = 0.02
    w = jax.random.uniform(key, (1000,), minval=-1.0, maxval=1.0)
    q = quant.quantize(w, step, 8, "dr")
    n, p = quant.code_bounds(8)
    inside = (w / step > n) & (w / step < p)
    err = jnp.abs(q - w)
    assert float(err[inside].max()) <= step / 2 + 1e-6


def test_sr_unbiased():
    """E[Q_S(w)] == w for w inside the representable range (key SR property)."""
    w = jnp.full((200000,), 0.01234)
    step = 0.01
    noise = quant.sr_noise(jax.random.PRNGKey(3), w.shape)
    q = quant.quantize(w, step, 8, "sr", noise)
    assert abs(float(q.mean()) - 0.01234) < 2e-5


def test_sr_identity_on_lattice():
    """SR of an exact lattice point never moves it (LPT untouched-row stability)."""
    codes = jnp.arange(-128, 128, dtype=jnp.int8).reshape(16, 16)
    step = jnp.full((16,), 0.03125)  # power of two -> exact float lattice
    w = quant.dequantize(codes, step)
    noise = quant.sr_noise(jax.random.PRNGKey(4), w.shape)
    codes2 = quant.quantize_codes(w, step, 8, "sr", noise)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))


@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    step=st.floats(1e-3, 1.0),
    val=st.floats(-5.0, 5.0),
)
def test_quantize_is_lattice_point(bits, step, val):
    """Q(w) is always Delta * integer within the code range."""
    q = float(quant.quantize(jnp.array([val]), step, bits, "dr")[0])
    code = q / step
    n, p = quant.code_bounds(bits)
    assert abs(code - round(code)) < 1e-4
    assert n - 0.01 <= code <= p + 0.01


def test_per_row_step_broadcast():
    w = jnp.ones((4, 8)) * 0.5
    step = jnp.array([0.1, 0.2, 0.5, 1.0])
    q = quant.quantize(w, step, 8, "dr")
    np.testing.assert_allclose(np.asarray(q[0]), 0.5, atol=1e-6)  # 0.5/0.1 = 5 exactly
    np.testing.assert_allclose(np.asarray(q[2]), 0.5, atol=1e-6)  # code 1 * 0.5
    np.testing.assert_allclose(np.asarray(q[3]), 1.0, atol=1e-6)  # 0.5 ties up -> 1


def test_lsq_step_gradient_matches_eq7():
    """Eq. 7: dQ/dDelta piecewise — check all three branches."""
    bits = 8
    n, p = quant.code_bounds(bits)
    step = jnp.array(0.1)
    w = jnp.array([-100.0, 100.0, 0.0314])  # below, above, inside
    grads = jax.grad(lambda s: jnp.sum(quant.fake_quant_lsq(w, s, bits, 1.0)))(step)
    scaled = 0.0314 / 0.1
    expected_inside = round(scaled) - scaled
    expected = n + p + expected_inside
    assert abs(float(grads) - expected) < 1e-4


def test_lsq_ste_weight_gradient():
    """STE: dQ/dw = 1 inside the clip range, 0 outside."""
    bits = 8
    step = jnp.array(0.1)
    w = jnp.array([-100.0, 0.05, 100.0])
    g = jax.grad(lambda x: jnp.sum(quant.fake_quant_lsq(x, step, bits, 1.0)))(w)
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 0.0], atol=1e-6)


def test_lsq_grad_scale_applies_to_step_only():
    bits = 8
    step = jnp.array(0.1)
    w = jnp.array([0.0314])
    g1 = jax.grad(lambda s: jnp.sum(quant.fake_quant_lsq(w, s, bits, 1.0)))(step)
    g2 = jax.grad(lambda s: jnp.sum(quant.fake_quant_lsq(w, s, bits, 0.5)))(step)
    assert abs(float(g2) - 0.5 * float(g1)) < 1e-6
    gw1 = jax.grad(lambda x: jnp.sum(quant.fake_quant_lsq(x, step, bits, 1.0)))(w)
    gw2 = jax.grad(lambda x: jnp.sum(quant.fake_quant_lsq(x, step, bits, 0.5)))(w)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2))


def test_pact_gradients():
    bits = 8
    alpha = jnp.array(1.0)
    w = jnp.array([-2.0, 0.5, 2.0])
    ga = jax.grad(lambda a: jnp.sum(quant.fake_quant_pact(w, a, bits)))(alpha)
    # Outside: sign(w) -> -1 + 1 = 0; inside contributes 0.
    assert abs(float(ga) - 0.0) < 1e-6
    gw = jax.grad(lambda x: jnp.sum(quant.fake_quant_pact(x, alpha, bits)))(w)
    np.testing.assert_allclose(np.asarray(gw), [0.0, 1.0, 0.0], atol=1e-6)


def test_init_step_size_positive():
    w = jnp.zeros((8, 4))
    s = quant.init_step_size(w, 8)
    assert s.shape == (8,)
    assert float(s.min()) > 0.0
