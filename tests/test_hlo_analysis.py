"""Validate the HLO analyzer against programs with known FLOPs/collectives.

Runs in a subprocess with 8 fake devices so the main test process keeps its
single-device view (per the dry-run isolation rule).
"""
import json
import textwrap

import pytest

from conftest import run_prog

PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_analysis

    mesh = jax.make_mesh((8,), ("model",))
    M, K, N, TRIPS = 64, 128, 256, 7

    def step(w1, w2, x):
        def body(c, _):
            c = jnp.tanh(c @ w1)  # [M,K] @ [K/8,N]-sharded + all-reduce
            c = c @ w2            # [M,N] @ [N,K] replicated
            return c, ()
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y.sum()

    w1_sh = NamedSharding(mesh, P("model", None))
    rep = NamedSharding(mesh, P(None, None))
    j = jax.jit(step, in_shardings=(w1_sh, rep, rep))
    comp = j.lower(
        jax.ShapeDtypeStruct((K, N), jnp.float32),
        jax.ShapeDtypeStruct((N, K), jnp.float32),
        jax.ShapeDtypeStruct((M, K), jnp.float32),
    ).compile()
    stats = hlo_analysis.analyze(comp.as_text())
    print(json.dumps(stats))
    """
)


@pytest.fixture(scope="module")
def stats():
    stdout = run_prog(PROG, timeout=300)
    return json.loads(stdout.strip().splitlines()[-1])


def test_flops_trip_count_multiplied(stats):
    M, K, N, TRIPS = 64, 128, 256, 7
    # GSPMD shards BOTH matmuls 8-way (verified from the HLO): per device and
    # iteration each dot contracts K/8 -> 2 * (2*M*N*K/8) FLOPs, x TRIPS.
    per_iter = 2 * (2 * M * N * (K // 8))
    expected = TRIPS * per_iter
    assert expected * 0.9 <= stats["flops"] <= expected * 1.3, stats["flops"]


def test_allreduce_counted_per_iteration(stats):
    # One all-reduce of [M, N] f32 per scan iteration, wire = 2x payload.
    M, N, TRIPS = 64, 256, 7
    expected = TRIPS * 2 * M * N * 4
    got = stats["collectives"].get("all-reduce", 0)
    assert expected * 0.9 <= got <= expected * 1.5, stats["collectives"]


def test_bytes_nonzero_and_sane(stats):
    M, K, N, TRIPS = 64, 128, 256, 7
    # At minimum, each iteration reads/writes the [M,N] activations a few
    # times; an absurdly small or huge number means the parser broke.
    floor = TRIPS * M * N * 4
    ceil = TRIPS * (M * N + M * K + K * N) * 4 * 50
    assert floor < stats["hbm_bytes"] < ceil, stats["hbm_bytes"]
