"""Distribution tests (8 fake devices in subprocesses): sharded train step ==
single-device train step; compressed int8 psum ~= exact psum; dry-run cell
machinery works end-to-end on a small mesh.
"""
import textwrap

import pytest

from conftest import run_prog

pytestmark = pytest.mark.dist


def test_sharded_train_step_matches_single_device():
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.dist import sharding, context as dist_ctx
        from repro.training import lm_trainer

        cfg = configs.smoke_config("qwen3-1.7b")
        cfg = dataclasses.replace(cfg, head_pad_multiple=2)
        tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
        batch = concrete_batch(cfg, batch=8, seq=64)
        step = lm_trainer.make_train_step(cfg, tcfg)
        init = functools.partial(lm_trainer.init_state, cfg=cfg, tcfg=tcfg)

        # Single device.
        s0 = init(jax.random.PRNGKey(0))
        s1, m1 = jax.jit(step)(s0, batch)

        # 4x2 mesh.
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pol = sharding.default_policy("qwen3-1.7b", multi_pod=False,
                                      model_size=2)
        st_sh = sharding.to_named(sharding.state_pspecs(cfg, pol, tcfg), mesh)
        b_sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                             batch)
        b_sh = sharding.to_named(
            sharding.batch_pspecs(b_sds, cfg, pol, mesh), mesh)
        with mesh, dist_ctx.use(mesh, pol):
            s0d = jax.jit(init, out_shardings=st_sh)(jax.random.PRNGKey(0))
            jit_step = jax.jit(step, in_shardings=(st_sh, b_sh),
                               out_shardings=(st_sh, NamedSharding(mesh, P())))
            s2, m2 = jit_step(s0d, batch)

        print("single", float(m1["loss"]), "sharded", float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
        # Table codes after one step agree almost everywhere (SR noise is
        # keyed identically; reductions reorder -> rare boundary flips).
        c1 = np.asarray(s1.table.codes)
        c2 = np.asarray(jax.device_get(s2.table.codes))
        frac = (c1 != c2).mean()
        print("code mismatch frac", frac)
        assert frac < 0.02
        print("MATCH_OK")
        """
    )
    assert "MATCH_OK" in run_prog(prog)


def test_compressed_psum_close_to_exact():
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.collectives import compressed_psum_local

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))

        def f(g, key):
            return compressed_psum_local(g, "data", key, bits=8)

        out = jax.jit(jax.shard_map(
            f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
            check_vma=False,
        ))(g, jax.random.PRNGKey(1))
        # Every rank contributed the same g -> exact psum = 8 * g.
        exact = 8.0 * g
        err = np.abs(np.asarray(out) - np.asarray(exact))
        rel = err.max() / np.abs(np.asarray(exact)).max()
        print("rel err", rel)
        assert rel < 0.02  # int8 quantization error bound
        print("PSUM_OK")
        """
    )
    assert "PSUM_OK" in run_prog(prog)


def test_hubert_head_replicated_on_16way():
    """vocab=504 cannot shard 16-way: policy must replicate the head."""
    from repro import configs
    from repro.dist import sharding

    cfg = configs.full_config("hubert-xlarge")
    pol = sharding.default_policy("hubert-xlarge", multi_pod=False)
    specs = sharding.param_pspecs(cfg, pol)
    assert specs["head"][0] is None


def test_production_mesh_shapes():
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.shape == {"data": 16, "model": 16}, m1.shape
        m2 = make_production_mesh(multi_pod=True)
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
        assert m2.devices.size == 512
        print("MESH_OK")
        """
    )
    assert "MESH_OK" in run_prog(prog)


def test_moe_ep_shard_map_matches_dense():
    """Explicit EP dispatch (all-to-all) == the dense GSPMD MoE at high
    capacity (no drops) — the §Perf deepseek-moe fix is semantics-preserving."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.models import moe as moe_mod

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = moe_mod.MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=64,
                                capacity_factor=16.0, n_shared_experts=1,
                                shared_d_ff=64)
        params = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
        y_ref, aux_ref = moe_mod.moe_forward(params, x, cfg)

        w_specs = {
            "router": P(None, None),
            "w_gate": P("model", None, None),
            "w_up": P("model", None, None),
            "w_down": P("model", None, None),
            "shared": {"w_gate": P(None, None), "w_up": P(None, None),
                       "w_down": P(None, None)},
        }
        def inner(p, xx):
            out, aux = moe_mod.moe_forward_ep(p, xx, cfg, axis="model")
            return out, jax.lax.pmean(aux, ("data", "model"))
        fn = jax.jit(jax.shard_map(
            inner, mesh=mesh,
            in_specs=(w_specs, P("data", None, None)),
            out_specs=(P("data", None, None), P()),
            check_vma=False,
        ))
        with mesh:
            y_ep, aux_ep = fn(params, x)
        err = np.abs(np.asarray(y_ep) - np.asarray(y_ref)).max()
        print("max err", err, "aux", float(aux_ep), float(aux_ref))
        assert err < 2e-5
        # aux estimates f_e per sequence-slice (EP) vs globally (dense):
        # statistically equivalent load-balance signals, not bit-equal.
        assert abs(float(aux_ep) - float(aux_ref)) < 0.3 * float(aux_ref)
        print("EP_OK")
        """
    )
    assert "EP_OK" in run_prog(prog)


def test_seq_parallel_train_step_matches_single_device():
    """`tp_sp` (sequence-parallel carries: T over 'model' for carry /
    activation hints) was spec'd but unexercised — the sharded train step
    must still match the single-device step."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.dist import sharding, context as dist_ctx
        from repro.training import lm_trainer

        cfg = configs.smoke_config("qwen3-1.7b")
        cfg = dataclasses.replace(cfg, head_pad_multiple=2)
        tcfg = lm_trainer.LMTrainerConfig(lr=1e-3)
        batch = concrete_batch(cfg, batch=8, seq=64)
        step = lm_trainer.make_train_step(cfg, tcfg)
        init = functools.partial(lm_trainer.init_state, cfg=cfg, tcfg=tcfg)

        s0 = init(jax.random.PRNGKey(0))
        s1, m1 = jax.jit(step)(s0, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pol = sharding.policy_from_name("tp_sp", model_size=2, data_size=4)
        assert pol.seq_parallel
        st_sh = sharding.to_named(sharding.state_pspecs(cfg, pol, tcfg), mesh)
        b_sds = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                             batch)
        b_sh = sharding.to_named(
            sharding.batch_pspecs(b_sds, cfg, pol, mesh), mesh)
        with mesh, dist_ctx.use(mesh, pol):
            s0d = jax.jit(init, out_shardings=st_sh)(jax.random.PRNGKey(0))
            jit_step = jax.jit(step, in_shardings=(st_sh, b_sh),
                               out_shardings=(st_sh, NamedSharding(mesh, P())))
            s2, m2 = jit_step(s0d, batch)

        print("single", float(m1["loss"]), "seq-parallel", float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
        c1 = np.asarray(s1.table.codes)
        c2 = np.asarray(jax.device_get(s2.table.codes))
        frac = (c1 != c2).mean()
        print("code mismatch frac", frac)
        assert frac < 0.02
        print("SP_OK")
        """
    )
    assert "SP_OK" in run_prog(prog)
