"""Checkpoint manager: atomic roundtrip, keep-k GC, resume, elastic reshard."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO_ROOT, SUBPROC_ENV, run_prog

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.checkpoint.manager import latest_step

jax.config.update("jax_platform_name", "cpu")


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "codes": jax.random.randint(k, (32, 8), -128, 128, jnp.int8),
        "step_sizes": jax.random.uniform(k, (32,)),
        "nested": {"w": jax.random.normal(k, (4, 4)), "count": jnp.asarray(7)},
    }


def test_roundtrip_preserves_dtypes(tmp_path):
    tree = make_tree()
    save_pytree(tree, tmp_path, step=10)
    restored, manifest = load_pytree(tree, tmp_path, step=10)
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype  # int8 codes stay int8
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, save_every=1)
    for s in (1, 2, 3, 4):
        mgr.maybe_save(make_tree(s), s)
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*") if p.is_dir())
    assert len(kept) == 2 and kept[-1] == "step_000000004"


def test_save_every_cadence(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5, save_every=10)
    assert not mgr.maybe_save(make_tree(), 5)
    assert mgr.maybe_save(make_tree(), 10)
    assert mgr.maybe_save(make_tree(), 7, force=True)


def test_leaf_count_mismatch_rejected(tmp_path):
    save_pytree(make_tree(), tmp_path, step=1)
    bad_template = {"only": jnp.zeros((2,))}
    with pytest.raises(ValueError):
        load_pytree(bad_template, tmp_path, step=1)


def test_uncommitted_checkpoint_invisible(tmp_path):
    save_pytree(make_tree(), tmp_path, step=3)
    # Simulate a crash between data write and commit-marker.
    (tmp_path / "step_000000003.COMMITTED").unlink()
    assert latest_step(tmp_path) is None


def test_elastic_reshard_across_device_counts(tmp_path):
    """Save on 1 device, restore sharded onto 8 fake devices (and back)."""
    tree = make_tree()
    save_pytree(tree, tmp_path, step=1)
    prog = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import load_pytree
        mesh = jax.make_mesh((8,), ("model",))
        template = {{
            "codes": jnp.zeros((32, 8), jnp.int8),
            "step_sizes": jnp.zeros((32,)),
            "nested": {{"w": jnp.zeros((4, 4)), "count": jnp.asarray(0)}},
        }}
        sh = {{
            "codes": NamedSharding(mesh, P("model", None)),
            "step_sizes": NamedSharding(mesh, P("model")),
            "nested": {{"w": NamedSharding(mesh, P()),
                        "count": NamedSharding(mesh, P())}},
        }}
        restored, m = load_pytree(template, r"{tmp_path}", step=1,
                                  shardings=sh)
        assert len(restored["codes"].sharding.device_set) == 8
        print("RESHARD_OK", int(np.asarray(restored["codes"]).sum()))
        """
    )
    stdout = run_prog(prog, timeout=300)
    assert "RESHARD_OK" in stdout
    expect = int(np.asarray(make_tree()["codes"], dtype=np.int64).sum())
    got = int(stdout.strip().split()[-1])
    assert got == expect  # content survives the reshard bit-exactly


def test_train_driver_resume(tmp_path):
    """launch.train: run 6 steps, kill, resume — loss continues, no restart."""
    cmd = [
        sys.executable, "-m", "repro.launch.train", "--arch", "smollm-135m",
        "--smoke", "--batch", "2", "--seq", "32", "--ckpt-every", "2",
        "--ckpt-dir", str(tmp_path), "--log-every", "1",
    ]
    out1 = subprocess.run(
        cmd + ["--steps", "4"], capture_output=True, text=True,
        env=dict(SUBPROC_ENV), cwd=REPO_ROOT, timeout=560,
    )
    assert out1.returncode == 0, out1.stderr[-2000:]
    out2 = subprocess.run(
        cmd + ["--steps", "8"], capture_output=True, text=True,
        env=dict(SUBPROC_ENV), cwd=REPO_ROOT, timeout=560,
    )
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step 4" in out2.stdout
