"""Tiered row storage (`repro.storage`): the PR-7 acceptance contract.

* **Bitwise training parity** — for every integer-table method, training with
  a device hot-row cache composed over the code storage produces the exact
  same state (codes, scales, optimizer moments, dense params) as training
  without one, under Zipf(1.1) traffic that forces real evictions and
  dirty-row write-backs.
* **Bitwise serving parity** — the Engine scores identically with the cache
  on, warm-started from id frequencies, restored from a serving checkpoint,
  or running cold-tier (host-resident codes) with a device budget smaller
  than the full table.
* **Accounting** — resident-bytes includes the cache rows *and* the cache
  metadata (id maps); `EngineMetrics.to_json()` is the stable schema and the
  dataclass still quacks like the legacy dict.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import methods
from repro.checkpoint import manager as ckpt
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.models.ctr import DCNConfig
from repro.serving.ctr import CTREngine, CTRRequest
from repro.serving.engine import CacheMetrics, EngineMetrics
from repro.storage import base as rowstore
from repro.storage.tiered import HotRowCache, TieredCodes
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

pytestmark = pytest.mark.storage

INT_METHODS = ["lpt", "alpt", "qr_lpt", "qr_alpt", "mixed"]

ZIPF_DATA = CTRDatasetConfig(
    name="storage-zipf", n_fields=4, cardinalities=(13, 17, 11, 23),
    teacher_rank=3, zipf_a=1.1, seed=5,
)


def _spec_for(method, *, n, d=8, bits=8):
    kw = dict(method=method, n=n, d=d, bits=bits, init_scale=0.05)
    if method.startswith("qr"):
        kw["hash_compression"] = 4.0
    if method == "mixed":
        # Four equal field groups at mixed widths covering the n-row table.
        q, r = divmod(n, 4)
        cards = (q, q, q, q + r)
        kw["field_cards"] = cards
        kw["field_bits"] = (8, 4, 8, 2)
    return methods.EmbeddingSpec(**kw)


def _trainer(method, *, cache_rows, data_cfg=ZIPF_DATA, d=8):
    spec = _spec_for(method, n=data_cfg.n_features, d=d)
    dcn = DCNConfig(n_fields=data_cfg.n_fields, emb_dim=d, cross_depth=1,
                    mlp_widths=(16,))
    return CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn,
                                    lr=1e-3, cache_rows=cache_rows))


def _train(trainer, data, steps, batch=16):
    state = trainer.init_state(jax.random.PRNGKey(0))
    for i in range(steps):
        ids, labels = data.batch("train", i, batch)
        state, _ = trainer.train_step(state, ids, labels)
    return state


def _tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ------------------------------------------------------- RowStore protocol


def test_rowstore_conformance_tiered():
    """TieredCodes satisfies the RowStore protocol, and the module-level
    dispatchers agree with plain-ndarray semantics."""
    rng = np.random.RandomState(0)
    base = jnp.asarray(rng.randint(-128, 128, (32, 8)), jnp.int8)
    cache = HotRowCache(4, 32, name="t")
    tiered = cache.wrap(base)
    assert rowstore.is_row_store(tiered)
    assert tiered.shape == (32, 8)

    # Admit rows {3, 7} so hot-overlay routing is actually exercised.
    moves = cache.observe(np.array([3, 7, 3, 7, 3, 7]))
    tiered = cache.apply(tiered, moves)
    moves = cache.observe(np.array([3, 7]))
    if moves is not None:
        tiered = cache.apply(tiered, moves)

    ids = jnp.asarray([0, 3, 7, 31, 3])
    assert np.array_equal(rowstore.take_rows(tiered, ids),
                          np.asarray(base)[np.asarray(ids)])
    assert np.array_equal(rowstore.logical_codes(tiered), base)

    # Writes route through the overlay but stay logically identical.
    new_rows = jnp.asarray(rng.randint(-128, 128, (3, 8)), jnp.int8)
    w_ids = jnp.asarray([3, 5, 7])
    t2 = rowstore.set_rows(tiered, w_ids, new_rows, mode="drop")
    want = np.asarray(base).copy()
    want[np.asarray(w_ids)] = np.asarray(new_rows)
    assert np.array_equal(rowstore.logical_codes(t2), want)
    assert np.array_equal(rowstore.take_rows(t2, ids), want[np.asarray(ids)])

    mask = jnp.zeros((32,), bool).at[jnp.asarray([3, 9])].set(True)
    repl = jnp.asarray(rng.randint(-128, 128, (32, 8)), jnp.int8)
    t3 = rowstore.where_rows(t2, mask, repl)
    want3 = np.where(np.asarray(mask)[:, None], np.asarray(repl), want)
    assert np.array_equal(rowstore.logical_codes(t3), want3)

    # Plain ndarrays pass through the same dispatchers unchanged.
    assert np.array_equal(rowstore.take_rows(base, ids),
                          np.asarray(base)[np.asarray(ids)])
    assert rowstore.resident_bytes_of(base) == 32 * 8
    assert rowstore.resident_bytes_of(tiered) > 32 * 8  # + hot + metadata


# ------------------------------------------------------- training parity


@pytest.mark.parametrize("method", INT_METHODS)
def test_train_parity_cache_on_equals_off(method):
    """Cache-on training is bitwise-equal to cache-off: every leaf of the
    exported state (codes, scales, moments, dense params) matches."""
    data = CTRSynthetic(ZIPF_DATA)
    off = _train(_trainer(method, cache_rows=0), data, steps=6)
    tr = _trainer(method, cache_rows=8)
    on = tr.export_state(_train(tr, data, steps=6))
    assert _tree_equal(off.emb_state, on.emb_state)
    assert _tree_equal(off.dense_params, on.dense_params)
    assert any(s["hits"] > 0 for s in tr.cache_stats())


def test_dirty_writeback_cycle():
    """A written row must survive evict -> re-admit.  Phased traffic against
    a 2-row cache: phase A writes rows {0, 1} dirty; phase B hammers rows
    {2, 3} until their lifetime frequency overtakes A's (dirty eviction +
    write-back); phase C returns to {0, 1} (re-admission).  The exported
    state still matches cache-off exactly."""
    rng = np.random.RandomState(7)
    phases = [(0, 1)] * 3 + [(2, 3)] * 6 + [(0, 1)] * 5
    batches = []
    for a, b in phases:
        ids = np.where(np.arange(32).reshape(8, 4) % 2 == 0, a, b)
        labels = rng.randint(0, 2, 8).astype(np.float32)
        batches.append((ids.astype(np.int32), labels))

    def run(cache_rows):
        tr = _trainer("alpt", cache_rows=cache_rows)
        state = tr.init_state(jax.random.PRNGKey(0))
        for ids, labels in batches:
            state, _ = tr.train_step(state, ids, labels)
        return tr, state

    _, off = run(0)
    tr, on_state = run(2)
    on = tr.export_state(on_state)
    assert _tree_equal(off.emb_state, on.emb_state)
    stats = tr.cache_stats()[0]
    assert stats["evictions"] > 0
    assert stats["writebacks"] > 0


# -------------------------------------------------------- serving parity


def _score_all(engine, ids):
    rids = [engine.submit(CTRRequest(rid=i, ids=row))
            for i, row in enumerate(ids)]
    done = engine.run()
    return [done[r]["prob"] for r in rids]


@pytest.mark.parametrize("method", INT_METHODS)
def test_engine_cache_parity(method):
    """Warm hot-tier scoring == uncached scoring, bit for bit, while the
    cache actually serves hits."""
    data = CTRSynthetic(ZIPF_DATA)
    tr = _trainer(method, cache_rows=0)
    state = _train(tr, data, steps=2)
    ids, _ = data.batch("test", 0, 24)

    plain = CTREngine.from_state(state, tr.cfg, batch=4)
    cached = CTREngine.from_state(state, tr.cfg, batch=4, cache_rows=8)
    assert _score_all(plain, ids) == _score_all(cached, ids)
    m = cached.metrics()
    assert m.caches and m.cache_hit_rate > 0.0


def test_engine_restart_warm_start(tmp_path):
    """Engine restart story: serving checkpoint -> from_checkpoint with a
    hot tier warm-started from training id frequencies.  Scores stay
    bitwise; the pre-admitted rows serve hits from the first wave."""
    data = CTRSynthetic(ZIPF_DATA)
    tr = _trainer("alpt", cache_rows=0)
    state = _train(tr, data, steps=2)
    n = tr.spec.n
    freqs = np.zeros(n, np.int64)
    for i in range(2):
        ids, _ = data.batch("train", i, 16)
        np.add.at(freqs, ids.reshape(-1), 1)

    ckpt.save_serving_checkpoint(
        tmp_path, step=2, params=state.dense_params,
        table=state.emb_state, spec=tr.spec,
    )
    live = CTREngine.from_state(state, tr.cfg, batch=4)
    restored = CTREngine.from_checkpoint(
        tmp_path, tr.cfg, state.dense_params, batch=4, cache_rows=8,
    )
    restored.warm_start(freqs)
    ids, _ = data.batch("test", 1, 12)
    assert _score_all(live, ids) == _score_all(restored, ids)
    m = restored.metrics()
    assert m.cache_hit_rate > 0.0
    assert all(c.rows_cached > 0 for c in m.caches)


def test_engine_cold_tier_parity_over_budget(tmp_path):
    """Cold tier serves a table whose codes exceed the device budget:
    host-resident codes, device holds scales + hot rows, scores bitwise."""
    data = CTRSynthetic(ZIPF_DATA)
    tr = _trainer("lpt", cache_rows=0)
    state = _train(tr, data, steps=2)

    plain = CTREngine.from_state(state, tr.cfg, batch=4)
    full_code_bytes = plain.embedding_code_bytes
    budget = full_code_bytes - 1  # the full table must NOT fit
    cold = CTREngine.from_state(
        state, tr.cfg, batch=4, cold_tier=True, cache_rows=8,
        device_budget_bytes=budget,
    )
    ids, _ = data.batch("test", 0, 24)
    assert _score_all(plain, ids) == _score_all(cold, ids)
    m = cold.metrics()
    assert m.resident_embedding_bytes <= budget
    assert m.caches[0].tier == "cold"
    assert m.cache_budget_bytes == budget

    # An over-budget *hot* configuration must refuse loudly instead.
    with pytest.raises(ValueError, match="budget"):
        CTREngine.from_state(
            state, tr.cfg, batch=4, cold_tier=True, cache_rows=8,
            device_budget_bytes=16,
        )


# ------------------------------------------------------------- accounting


def test_resident_bytes_include_cache_metadata():
    """Composing a hot tier grows resident-bytes by the cached rows AND the
    id-map metadata — the cache is never free in the accounting."""
    data = CTRSynthetic(ZIPF_DATA)
    tr = _trainer("alpt", cache_rows=0)
    state = _train(tr, data, steps=1)
    plain = CTREngine.from_state(state, tr.cfg, batch=4)
    cached = CTREngine.from_state(state, tr.cfg, batch=4, cache_rows=8)
    pm, cm = plain.metrics(), cached.metrics()
    hot = cm.caches[0]
    assert hot.metadata_bytes > 0
    assert cm.resident_embedding_bytes >= (
        pm.resident_embedding_bytes + hot.hot_bytes
    )
    # The TieredCodes store itself reports the same breakdown.
    slot = methods.get(tr.spec.method).storage_spec(tr.spec)[0]
    codes = slot.get(cached.table).codes
    assert isinstance(codes, TieredCodes)
    assert codes.resident_bytes == (
        rowstore.resident_bytes_of(codes.backing)
        + codes.hot_bytes + codes.metadata_bytes
    )


def test_engine_metrics_schema_and_dict_compat():
    """EngineMetrics.to_json() is the stable wire schema; the dataclass
    doubles as a read-only mapping for legacy consumers."""
    data = CTRSynthetic(ZIPF_DATA)
    tr = _trainer("lpt", cache_rows=0)
    state = _train(tr, data, steps=1)
    engine = CTREngine.from_state(state, tr.cfg, batch=4, cache_rows=8)
    ids, _ = data.batch("test", 0, 8)
    _score_all(engine, ids)

    m = engine.metrics()
    assert isinstance(m, EngineMetrics)
    j = m.to_json()
    for key in ["scenario", "embedding_method", "requests_submitted",
                "requests_completed", "steps", "wall_s",
                "resident_embedding_bytes", "embedding_code_bytes",
                "embedding_scale_bytes", "int8_resident",
                "kernel_fallbacks", "us_per_request", "caches",
                "cache_hit_rate", "prefetch_depth"]:
        assert key in j, key
    assert all(isinstance(c, dict) for c in j["caches"])
    assert set(j["caches"][0]) == {
        f.name for f in dataclasses.fields(CacheMetrics)
    }
    # Legacy mapping shim: index / .get / spread all keep working.
    assert m["scenario"] == "ctr"
    assert m.get("tokens_generated", 0) == 0
    assert {**m} == j
    # And the uncached engine omits the cache keys (conditional schema).
    plain = CTREngine.from_state(state, tr.cfg, batch=4)
    assert "caches" not in plain.metrics().to_json()
