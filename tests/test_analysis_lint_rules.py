"""Fixture tests for the AST lint rules in repro.analysis.lint.rules.

Each rule gets positive snippets (the violation fires, at the right line)
and negative snippets (clean code — including the regex-era false-positive
classes this engine exists to eliminate: docstrings, comments, aliased
imports, keyword-dtype variants).
"""
import textwrap

import pytest

from repro.analysis.lint import check_snippet


def hits(text, rule, rel="src/repro/x.py"):
    return check_snippet(textwrap.dedent(text), rule, rel=rel)


# ------------------------------------------------------- no-string-dispatch


class TestNoStringDispatch:
    def test_eq_comparison_fires(self):
        found = hits('if spec.method == "lpt":\n    pass\n',
                     "no-string-dispatch")
        assert len(found) == 1 and found[0].line == 1

    def test_membership_fires(self):
        found = hits('ok = cfg.embedding_method in ("lpt", "alpt")\n',
                     "no-string-dispatch")
        assert len(found) == 1

    def test_match_statement_fires(self):
        found = hits(
            '''
            match spec.method:
                case "lpt":
                    pass
                case _:
                    pass
            ''',
            "no-string-dispatch")
        assert len(found) == 1

    def test_startswith_fires(self):
        found = hits('if spec.method.startswith("qr"):\n    pass\n',
                     "no-string-dispatch")
        assert len(found) == 1

    def test_methods_package_exempt(self):
        found = hits('if spec.method == "lpt":\n    pass\n',
                     "no-string-dispatch",
                     rel="src/repro/methods/registry.py")
        assert found == []

    def test_docstring_mention_is_clean(self):
        # The regex-era false positive: prose that *mentions* dispatch.
        found = hits(
            '''
            def f():
                """Removed every `spec.method == "lpt"` chain."""
                return 1
            ''',
            "no-string-dispatch")
        assert found == []

    def test_string_literal_is_clean(self):
        found = hits(
            'msg = "do not write cfg.embedding_method in (\'lpt\',)"\n',
            "no-string-dispatch")
        assert found == []

    def test_unrelated_attr_comparison_is_clean(self):
        found = hits('if spec.model == "dcn":\n    pass\n',
                     "no-string-dispatch")
        assert found == []


# -------------------------------------------------------- no-raw-code-casts


class TestNoRawCodeCasts:
    def test_astype_int8_fires(self):
        found = hits(
            'import jax.numpy as jnp\ncodes = x.astype(jnp.int8)\n',
            "no-raw-code-casts")
        assert len(found) == 1 and found[0].line == 2

    def test_astype_uint8_fires(self):
        found = hits(
            'import jax.numpy as jnp\ncodes = x.astype(jnp.uint8)\n',
            "no-raw-code-casts")
        assert len(found) == 1

    def test_aliased_import_fires(self):
        # Regex false negative: `import jax.numpy as np` hid the cast.
        found = hits(
            'import jax.numpy as np\ncodes = x.astype(np.int8)\n',
            "no-raw-code-casts")
        assert len(found) == 1

    def test_asarray_dtype_kwarg_fires(self):
        found = hits(
            'import jax.numpy as jnp\nc = jnp.asarray(x, dtype=jnp.int8)\n',
            "no-raw-code-casts")
        assert len(found) == 1

    def test_convert_element_type_fires(self):
        found = hits(
            'import jax\nimport jax.numpy as jnp\n'
            'c = jax.lax.convert_element_type(x, jnp.int8)\n',
            "no-raw-code-casts")
        assert len(found) == 1

    def test_string_dtype_fires(self):
        found = hits('codes = x.astype("int8")\n', "no-raw-code-casts")
        assert len(found) == 1

    def test_float_cast_is_clean(self):
        found = hits(
            'import jax.numpy as jnp\nw = x.astype(jnp.float32)\n',
            "no-raw-code-casts")
        assert found == []

    def test_comment_mention_is_clean(self):
        found = hits('# the old code did x.astype(jnp.int8)\nw = x\n',
                     "no-raw-code-casts")
        assert found == []

    def test_codestore_exempt(self):
        found = hits(
            'import jax.numpy as jnp\ncodes = x.astype(jnp.int8)\n',
            "no-raw-code-casts", rel="src/repro/core/codestore.py")
        assert found == []

    def test_kernels_exempt(self):
        found = hits(
            'import jax.numpy as jnp\ncodes = x.astype(jnp.int8)\n',
            "no-raw-code-casts", rel="src/repro/kernels/ops.py")
        assert found == []


# -------------------------------------------------- no-direct-storage-access


class TestNoDirectStorageAccess:
    def test_container_unpack_fires(self):
        found = hits('codes = store.unpack()\n', "no-direct-storage-access")
        assert len(found) == 1

    def test_container_take_fires(self):
        found = hits('rows = table.codes.take(ids)\n',
                     "no-direct-storage-access")
        assert len(found) == 1

    def test_pack_codes_fires(self):
        found = hits(
            'from repro.core.codestore import pack_codes\n'
            'p = pack_codes(codes, 4)\n',
            "no-direct-storage-access")
        assert len(found) == 1

    def test_module_receiver_is_clean(self):
        # The seam itself: import-bound receivers are modules, not
        # containers — rowstore.set_rows(...) is the blessed path.
        found = hits(
            'from repro.storage import base as rowstore\n'
            'store = rowstore.set_rows(store, ids, rows)\n',
            "no-direct-storage-access")
        assert found == []

    def test_self_receiver_is_clean(self):
        found = hits(
            'class C:\n'
            '    def f(self, ids):\n'
            '        return self.take(ids)\n',
            "no-direct-storage-access")
        assert found == []

    def test_take_with_axis_kwarg_is_clean(self):
        # numpy-style take(ids, axis=0) is an ndarray take, not the seam.
        found = hits('rows = arr.take(ids, axis=0)\n',
                     "no-direct-storage-access")
        assert found == []

    def test_storage_layer_exempt(self):
        found = hits('codes = store.unpack()\n', "no-direct-storage-access",
                     rel="src/repro/storage/tiered.py")
        assert found == []

    def test_collectives_pack_exempt(self):
        found = hits(
            'from repro.core.codestore import pack_codes\n'
            'p = pack_codes(codes, 4)\n',
            "no-direct-storage-access",
            rel="src/repro/dist/collectives.py")
        assert found == []


# ---------------------------------------------------------- rng-key-discipline


class TestRngKeyDiscipline:
    def test_double_consume_fires(self):
        found = hits(
            '''
            import jax
            def f(key, shape):
                a = jax.random.normal(key, shape)
                b = jax.random.uniform(key, shape)
                return a + b
            ''',
            "rng-key-discipline")
        assert len(found) == 1

    def test_split_then_use_is_clean(self):
        found = hits(
            '''
            import jax
            def f(key, shape):
                k1, k2 = jax.random.split(key)
                a = jax.random.normal(k1, shape)
                b = jax.random.uniform(k2, shape)
                return a + b
            ''',
            "rng-key-discipline")
        assert found == []

    def test_fold_in_is_nonconsuming(self):
        found = hits(
            '''
            import jax
            def f(key, shape):
                a = jax.random.normal(jax.random.fold_in(key, 0), shape)
                b = jax.random.normal(jax.random.fold_in(key, 1), shape)
                return a + b
            ''',
            "rng-key-discipline")
        assert found == []

    def test_branch_exclusive_use_is_clean(self):
        found = hits(
            '''
            import jax
            def f(key, shape, flag):
                if flag:
                    return jax.random.normal(key, shape)
                return jax.random.uniform(key, shape)
            ''',
            "rng-key-discipline")
        assert found == []

    def test_loop_reuse_fires(self):
        found = hits(
            '''
            import jax
            def f(key, shape, xs):
                out = []
                for x in xs:
                    out.append(jax.random.normal(key, shape))
                return out
            ''',
            "rng-key-discipline")
        assert len(found) == 1

    def test_reassignment_resets_count(self):
        found = hits(
            '''
            import jax
            def f(key, shape):
                a = jax.random.normal(key, shape)
                key = jax.random.fold_in(key, 1)
                b = jax.random.normal(key, shape)
                return a + b
            ''',
            "rng-key-discipline")
        assert found == []


# ----------------------------------------------------------- no-silent-fallback


class TestNoSilentFallback:
    REL = "src/repro/kernels/ops.py"

    def test_unnoted_fallback_fires(self):
        found = hits(
            '''
            def fused_gather(codes, ids):
                if codes.ndim != 2:
                    return _ref_gather(codes, ids)
                return _pallas_gather(codes, ids)
            ''',
            "no-silent-fallback", rel=self.REL)
        assert len(found) == 1

    def test_noted_fallback_is_clean(self):
        found = hits(
            '''
            def fused_gather(codes, ids):
                if codes.ndim != 2:
                    _note_fallback("gather", "ndim")
                    return _ref_gather(codes, ids)
                return _pallas_gather(codes, ids)
            ''',
            "no-silent-fallback", rel=self.REL)
        assert found == []

    def test_use_kernel_switch_is_clean(self):
        # The explicit off-switch is configuration, not a fallback.
        found = hits(
            '''
            def fused_gather(codes, ids, use_kernel=True):
                if not use_kernel:
                    return _ref_gather(codes, ids)
                return _pallas_gather(codes, ids)
            ''',
            "no-silent-fallback", rel=self.REL)
        assert found == []

    def test_ref_calling_ref_is_clean(self):
        found = hits(
            '''
            def _ref_gather_sum(codes, ids):
                return _ref_gather(codes, ids).sum()
            ''',
            "no-silent-fallback", rel=self.REL)
        assert found == []

    def test_outside_kernels_not_checked(self):
        found = hits(
            '''
            def fused_gather(codes, ids):
                return _ref_gather(codes, ids)
            ''',
            "no-silent-fallback", rel="src/repro/core/lpt.py")
        assert found == []


# -------------------------------------------------------- no-unfenced-model-grad


class TestNoUnfencedModelGrad:
    REL = "src/repro/methods/lpt.py"

    def test_direct_grad_invocation_fires(self):
        found = hits(
            '''
            import jax
            def step(params, batch):
                g = jax.grad(loss)(params, batch)
                return g
            ''',
            "no-unfenced-model-grad", rel=self.REL)
        assert len(found) == 1

    def test_value_and_grad_invocation_fires(self):
        found = hits(
            '''
            import jax
            def step(params, batch):
                loss_val, g = jax.value_and_grad(loss)(params, batch)
                return g
            ''',
            "no-unfenced-model-grad", rel=self.REL)
        assert len(found) == 1

    def test_fenced_grad_is_clean(self):
        # Constructing the callable and handing it to fence_call is the
        # contract — the fence invokes it.
        found = hits(
            '''
            import jax
            from repro.core import fence
            def step(params, batch):
                g = fence.fence_call(jax.grad(loss), params, batch)
                return g
            ''',
            "no-unfenced-model-grad", rel=self.REL)
        assert found == []

    def test_dense_delta_grad_exempt(self):
        found = hits(
            '''
            import jax
            def dense_delta_grad(params, batch):
                return jax.grad(loss)(params, batch)
            ''',
            "no-unfenced-model-grad", rel=self.REL)
        assert found == []

    def test_fence_module_exempt(self):
        found = hits(
            '''
            import jax
            def fence_call(fn, *args):
                return jax.grad(fn)(*args)
            ''',
            "no-unfenced-model-grad", rel="src/repro/core/fence.py")
        assert found == []


# ------------------------------------------------------------ no-silent-except


class TestNoSilentExcept:
    def test_bare_except_pass_fires(self):
        found = hits(
            '''
            try:
                risky()
            except:
                pass
            ''',
            "no-silent-except")
        assert len(found) == 1 and found[0].line == 4

    def test_except_exception_pass_fires(self):
        found = hits(
            '''
            try:
                risky()
            except Exception:
                pass
            ''',
            "no-silent-except")
        assert len(found) == 1

    def test_except_exception_silent_return_fires(self):
        # Returning a default is still silent: no raise, log, or counter.
        found = hits(
            '''
            def f():
                try:
                    return risky()
                except Exception:
                    return None
            ''',
            "no-silent-except")
        assert len(found) == 1

    def test_tuple_containing_exception_fires(self):
        found = hits(
            '''
            try:
                risky()
            except (ValueError, Exception):
                pass
            ''',
            "no-silent-except")
        assert len(found) == 1

    def test_reraise_is_clean(self):
        found = hits(
            '''
            try:
                risky()
            except Exception:
                cleanup()
                raise
            ''',
            "no-silent-except")
        assert found == []

    def test_logging_is_clean(self):
        found = hits(
            '''
            try:
                risky()
            except Exception as e:
                logger.warning("risky failed: %s", e)
            ''',
            "no-silent-except")
        assert found == []

    def test_counter_tick_is_clean(self):
        found = hits(
            '''
            class C:
                def f(self):
                    try:
                        risky()
                    except Exception:
                        self.failures += 1
            ''',
            "no-silent-except")
        assert found == []

    def test_failure_list_append_is_clean(self):
        found = hits(
            '''
            class C:
                def f(self):
                    try:
                        risky()
                    except Exception:
                        self.corrupt_steps.append(1)
            ''',
            "no-silent-except")
        assert found == []

    def test_narrow_handler_is_clean(self):
        # Catching a *specific* failure silently is a decision, not a hole.
        found = hits(
            '''
            try:
                risky()
            except ValueError:
                pass
            ''',
            "no-silent-except")
        assert found == []

    def test_docstring_mention_is_clean(self):
        found = hits(
            '''
            def f():
                """Never write `except Exception: pass` in src/."""
                return 1
            ''',
            "no-silent-except")
        assert found == []


# ------------------------------------------------------------- suppressions


class TestSuppressions:
    def test_line_scoped_suppression(self, tmp_path):
        from repro.analysis.findings import Finding, load_suppressions
        supp_file = tmp_path / "supp.txt"
        supp_file.write_text(
            "# reviewed\nno-raw-code-casts src/repro/x.py:3\n")
        supp = load_suppressions(supp_file)
        hit = Finding(rule="no-raw-code-casts", path="src/repro/x.py",
                      line=3, message="m")
        miss = Finding(rule="no-raw-code-casts", path="src/repro/x.py",
                       line=9, message="m")
        kept = supp.apply([hit, miss])
        assert kept == [miss]
        assert supp.unused() == []

    def test_unused_entries_reported(self, tmp_path):
        from repro.analysis.findings import load_suppressions
        supp_file = tmp_path / "supp.txt"
        supp_file.write_text("no-string-dispatch src/repro/never.py\n")
        supp = load_suppressions(supp_file)
        assert supp.apply([]) == []
        assert len(supp.unused()) == 1

    def test_glob_and_rule_wildcard(self, tmp_path):
        from repro.analysis.findings import Finding, load_suppressions
        supp_file = tmp_path / "supp.txt"
        supp_file.write_text("* benchmarks/*.py\n")
        supp = load_suppressions(supp_file)
        f = Finding(rule="anything", path="benchmarks/kernel_bench.py",
                    line=1, message="m")
        assert supp.apply([f]) == []


# ------------------------------------------------------------------ catalog


def test_rule_catalog_complete():
    from repro.analysis.lint import all_rules
    names = {r.name for r in all_rules()}
    assert names == {
        "no-string-dispatch", "no-raw-code-casts",
        "no-direct-storage-access", "rng-key-discipline",
        "no-silent-fallback", "no-unfenced-model-grad",
        "no-silent-except", "no-host-sync",
    }


def test_repo_tree_is_clean():
    """The shipped tree passes its own lint gate (modulo the reviewed
    suppression file) — the property CI enforces."""
    from repro.analysis.findings import load_suppressions
    from repro.analysis.lint import REPO_ROOT, run_lint
    supp = load_suppressions(REPO_ROOT / "analysis-suppressions.txt")
    findings = supp.apply(run_lint())
    assert findings == [], "\n".join(f.format() for f in findings)
