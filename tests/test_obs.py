"""The PR-10 observability contracts (`repro.obs`).

* **Registry semantics** — typed counters/gauges with label tuples,
  snapshot/diff isolating one window, the stable ``repro/obs/v1`` schema,
  and loud kind/label mismatches.
* **Tracing** — span nesting lands in Chrome-trace complete events, the
  disabled path is a shared null context (no events, no allocation), and
  export round-trips through JSON.
* **Quantiles** — the P² estimator tracks numpy.percentile on thousands of
  samples and is exact below its marker count.
* **Bitwise parity (the hard contract)** — an instrumented run (tracer
  armed, every surface registering) produces bit-identical training state,
  losses, and Engine outputs to an uninstrumented run: spans never enter
  traced code.
* **Perf gate** — seeded baselines pass against their own artifacts and
  fail on synthetic regressions, missing cells, and missing artifacts.
* **Legacy schemas** — ``ops.fallback_stats()`` and
  ``EngineMetrics.to_json()`` keep their pre-registry keys bit-for-bit.
"""
import json

import jax
import numpy as np
import pytest

from repro import methods
from repro.data.ctr_synth import CTRDatasetConfig, CTRSynthetic
from repro.kernels import ops
from repro.models.ctr import DCNConfig
from repro.obs import counters as obs_counters
from repro.obs import gate
from repro.obs.counters import Counter, Gauge, Registry
from repro.obs.stats import P2Quantile, StreamingQuantiles
from repro.obs.trace import Tracer, tracer
from repro.serving.ctr import CTREngine, CTRRequest
from repro.training.ctr_trainer import CTRTrainer, TrainerConfig

jax.config.update("jax_platform_name", "cpu")

OBS_DATA = CTRDatasetConfig(
    name="obs", n_fields=4, cardinalities=(13, 29, 7, 53),
    teacher_rank=2, seed=0,
)


@pytest.fixture(autouse=True)
def _quiet_tracer():
    """The tracer is process-global; never leak an armed one across tests."""
    tracer().disable()
    tracer().clear()
    yield
    tracer().disable()
    tracer().clear()


def _trainer(method="lpt", bits=8):
    spec = methods.EmbeddingSpec(
        method=method, n=sum(OBS_DATA.cardinalities), d=8, bits=bits,
        init_scale=0.05,
    )
    dcn = DCNConfig(n_fields=OBS_DATA.n_fields, emb_dim=8, cross_depth=1,
                    mlp_widths=(16,))
    return CTRTrainer(TrainerConfig(spec=spec, model="dcn", dcn=dcn))


# ---------------------------------------------------------------- registry


class TestRegistry:
    def test_counter_inc_and_value(self):
        reg = Registry()
        c = reg.counter("t.hits")
        c.inc()
        c.inc(4)
        assert c.value() == 5

    def test_counter_rejects_negative(self):
        c = Registry().counter("t.hits")
        with pytest.raises(ValueError, match="cannot decrease"):
            c.inc(-1)

    def test_labeled_cells(self):
        reg = Registry()
        c = reg.counter("t.fallbacks", labels=("op", "reason"))
        c.inc(1, "gather", "shape")
        c.inc(2, "gather", "shape")
        c.inc(1, "update", "forced")
        assert c.value("gather", "shape") == 3
        assert c.value("update", "forced") == 1
        assert c.value("gather", "nope") == 0

    def test_label_arity_checked(self):
        c = Registry().counter("t.x", labels=("op",))
        with pytest.raises(ValueError, match="takes labels"):
            c.inc(1, "a", "b")

    def test_gauge_last_value_wins(self):
        g = Registry().gauge("t.bytes")
        g.set(100)
        g.set(42)
        assert g.value() == 42

    def test_get_or_create_is_same_object(self):
        reg = Registry()
        assert reg.counter("t.a", labels=("x",)) is reg.counter(
            "t.a", labels=("x",))

    def test_kind_mismatch_raises(self):
        reg = Registry()
        reg.counter("t.a")
        with pytest.raises(TypeError, match="already registered as counter"):
            reg.gauge("t.a")

    def test_label_mismatch_raises(self):
        reg = Registry()
        reg.counter("t.a", labels=("x",))
        with pytest.raises(ValueError, match="labels"):
            reg.counter("t.a", labels=("y",))

    def test_snapshot_diff_isolates_window(self):
        reg = Registry()
        c = reg.counter("t.n", labels=("op",))
        g = reg.gauge("t.depth")
        c.inc(5, "a")
        g.set(3)
        before = reg.snapshot()
        c.inc(2, "a")
        c.inc(1, "b")
        g.set(9)
        delta = reg.snapshot().diff(before)
        assert delta.value("t.n", "a") == 2
        assert delta.value("t.n", "b") == 1
        assert delta.value("t.depth") == 9  # gauges keep the later value

    def test_snapshot_is_point_in_time(self):
        reg = Registry()
        c = reg.counter("t.n")
        c.inc()
        snap = reg.snapshot()
        c.inc(10)
        assert snap.value("t.n") == 1

    def test_to_json_schema(self):
        reg = Registry()
        reg.counter("t.plain").inc(7)
        reg.counter("t.labeled", labels=("op",)).inc(2, "gather")
        reg.gauge("t.depth").set(3)
        doc = reg.to_json()
        assert doc["schema"] == "repro/obs/v1"
        assert doc["counters"]["t.plain"] == 7
        assert doc["counters"]["t.labeled"] == [
            {"labels": {"op": "gather"}, "value": 2}
        ]
        assert doc["gauges"]["t.depth"] == 3
        json.dumps(doc)  # wire schema must actually serialize

    def test_reset_zeroes_but_keeps_registrations(self):
        reg = Registry()
        c = reg.counter("t.n")
        c.inc(5)
        reg.reset()
        assert c.value() == 0
        assert "t.n" in reg.names()

    def test_global_registry_shared(self):
        assert obs_counters.registry() is obs_counters.registry()


# ----------------------------------------------------------------- tracing


class TestTracer:
    def test_disabled_span_is_shared_null_cm(self):
        t = Tracer()
        assert t.span("a") is t.span("b")  # no per-call allocation
        with t.span("a"):
            pass
        assert t.events == []

    def test_span_nesting_chrome_events(self):
        t = Tracer()
        t.enable()
        with t.span("train.step", step=3):
            with t.span("train.writeback"):
                pass
        evs = t.events
        assert [e["name"] for e in evs] == ["train.writeback", "train.step"]
        inner, outer = evs
        assert outer["ph"] == "X" and inner["ph"] == "X"
        assert outer["cat"] == "train"
        assert outer["args"] == {"step": 3}
        # nesting: the inner complete event sits inside the outer's window
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3

    def test_instant_and_async_events(self):
        t = Tracer()
        t.enable()
        t.async_begin("engine.request", 7, scenario="ctr")
        t.instant("train.straggler", step=5)
        t.async_end("engine.request", 7)
        phs = [e["ph"] for e in t.events]
        assert phs == ["b", "i", "e"]
        assert t.events[0]["id"] == 7

    def test_export_round_trips(self, tmp_path):
        t = Tracer()
        t.enable(str(tmp_path / "trace.json"))
        with t.span("ckpt.save", step=1):
            pass
        path = t.export()
        doc = json.loads(open(path).read())
        assert doc["displayTimeUnit"] == "ms"
        assert doc["traceEvents"][0]["name"] == "ckpt.save"

    def test_export_nowhere_is_none(self):
        assert Tracer().export() is None

    def test_fence_passthrough_when_disabled(self):
        t = Tracer()
        x = object()
        assert t.fence(x) is x
        assert t.fence(None) is None


# --------------------------------------------------------------- quantiles


class TestQuantiles:
    def test_exact_below_marker_count(self):
        p = P2Quantile(0.5)
        for v in (5.0, 1.0, 3.0):
            p.add(v)
        assert p.value() == 3.0  # exact median of a tiny sample

    def test_empty_is_nan(self):
        assert np.isnan(P2Quantile(0.5).value())

    @pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
    def test_tracks_numpy_percentile(self, q):
        rng = np.random.RandomState(0)
        xs = rng.lognormal(mean=3.0, sigma=0.7, size=5000)
        est = P2Quantile(q)
        for x in xs:
            est.add(float(x))
        exact = float(np.percentile(xs, q * 100))
        spread = float(np.percentile(xs, 99) - np.percentile(xs, 1))
        assert abs(est.value() - exact) <= 0.05 * spread

    def test_streaming_summary_json(self):
        s = StreamingQuantiles()
        assert s.to_json() == {"count": 0}
        for v in range(1, 101):
            s.add(float(v))
        doc = s.to_json()
        assert doc["count"] == 100
        assert doc["min"] == 1.0 and doc["max"] == 100.0
        assert doc["mean"] == pytest.approx(50.5)
        assert doc["p50"] == pytest.approx(50.5, rel=0.1)
        assert doc["p95"] == pytest.approx(95.0, rel=0.1)
        assert set(doc) == {"count", "mean", "min", "max",
                            "p50", "p95", "p99"}


# ---------------------------------------------------- bitwise parity (hard)


def _train_losses_and_state(method, steps=4):
    data = CTRSynthetic(OBS_DATA)
    tr = _trainer(method)
    state = tr.init_state()
    losses = []
    for i in range(steps):
        ids, labels = data.batch("train", i, 32)
        state, m = tr.train_step(state, ids, labels)
        losses.append(np.asarray(m["loss"]).tobytes())
    exported = jax.tree.leaves(tr.export_state(state))
    return losses, [np.asarray(x).tobytes() for x in exported]


@pytest.mark.parametrize("method", ["lpt", "alpt"])
def test_instrumented_training_bitwise_equal(method):
    base_losses, base_state = _train_losses_and_state(method)
    tracer().enable()
    try:
        inst_losses, inst_state = _train_losses_and_state(method)
    finally:
        tracer().disable()
        tracer().clear()
    assert inst_losses == base_losses
    assert inst_state == base_state


def _engine_probs():
    data = CTRSynthetic(OBS_DATA)
    tr = _trainer("alpt")
    state = tr.init_state()
    for i in range(2):
        ids, labels = data.batch("train", i, 32)
        state, _ = tr.train_step(state, ids, labels)
    engine = CTREngine.from_state(state, tr.cfg, batch=8)
    ids, _ = data.batch("test", 0, 16)
    rids = [engine.submit(CTRRequest(ids=row)) for row in ids]
    done = engine.run()
    return [done[r]["prob"] for r in rids]


def test_instrumented_engine_bitwise_equal():
    base = _engine_probs()
    tracer().enable()
    try:
        inst = _engine_probs()
    finally:
        tracer().disable()
        tracer().clear()
    assert inst == base  # exact float equality, not approx


def test_engine_latency_quantiles_reported():
    tracer().clear()
    _ = _engine_probs  # parity helper reused for a metrics-shape check
    data = CTRSynthetic(OBS_DATA)
    tr = _trainer("alpt")
    state = tr.init_state()
    engine = CTREngine.from_state(state, tr.cfg, batch=8)
    ids, _ = data.batch("test", 0, 16)
    for row in ids:
        engine.submit(CTRRequest(ids=row))
    engine.run()
    m = engine.metrics()
    assert m.latency_us is not None
    for which in ("wave", "request"):
        q = m.latency_us[which]
        assert q["count"] > 0
        assert q["p50"] <= q["p95"] <= q["p99"]
    # the serving cells' BENCH spread picks the key up automatically
    assert "latency_us" in dict(m)


# ------------------------------------------------------------------- gate


def _e2e_doc(us=100.0, packed=512, fallbacks=0):
    return {
        "schema": "repro/e2e_step_bench/v1",
        "cells": {
            "ctr/bits8/kernels_on": {
                "us_per_step": us,
                "packed_bytes": packed,
                "shape_fallbacks": fallbacks,
                "table_rows": 128,  # ungated: informational
            },
        },
        "obs_overhead": {"overhead_frac": 0.01},
    }


class TestGate:
    def test_seed_then_self_compare_passes(self):
        doc = _e2e_doc()
        base = gate.seed_baseline({"BENCH_X.json": doc})
        assert base["schema"] == gate.SCHEMA
        assert gate.compare(base, {"BENCH_X.json": doc}) == []

    def test_time_regression_fails_past_tolerance(self):
        base = gate.seed_baseline({"BENCH_X.json": _e2e_doc(us=100.0)})
        # default time tol 1.5 => allowed 250us
        assert gate.compare(base, {"BENCH_X.json": _e2e_doc(us=240.0)}) == []
        bad = gate.compare(base, {"BENCH_X.json": _e2e_doc(us=260.0)})
        assert len(bad) == 1 and bad[0].metric == "us_per_step"

    def test_bytes_and_count_are_exact(self):
        base = gate.seed_baseline({"BENCH_X.json": _e2e_doc()})
        grown = gate.compare(base, {"BENCH_X.json": _e2e_doc(packed=513)})
        assert [f.metric for f in grown] == ["packed_bytes"]
        fell = gate.compare(base, {"BENCH_X.json": _e2e_doc(fallbacks=1)})
        assert [f.metric for f in fell] == ["shape_fallbacks"]

    def test_missing_cell_and_artifact_are_findings(self):
        base = gate.seed_baseline({"BENCH_X.json": _e2e_doc()})
        none = gate.compare(base, {})
        assert any("missing" in f.message for f in none)
        empty = gate.compare(base, {"BENCH_X.json": {"cells": {}}})
        assert any(f.cell == "ctr/bits8/kernels_on" for f in empty)

    def test_fresh_extra_cells_pass(self):
        base = gate.seed_baseline({"BENCH_X.json": _e2e_doc()})
        doc = _e2e_doc()
        doc["cells"]["ctr/bits4/kernels_on"] = {"us_per_step": 1e9}
        assert gate.compare(base, {"BENCH_X.json": doc}) == []

    def test_serving_list_cells_named_and_rate_gated(self):
        doc = {"cells": [{
            "scenario": "ctr", "embedding_method": "alpt",
            "cache_rows": 409, "cold_tier": True,
            "us_per_request": 50.0, "cache_hit_rate": 0.9,
            "latency_us": {"wave": {"p95": 1000.0}},
        }]}
        base = gate.seed_baseline({"BENCH_Y.json": doc})
        cells = base["benches"]["BENCH_Y.json"]["cells"]
        assert list(cells) == ["ctr/alpt/cold"]
        assert "latency_us.wave.p95" in cells["ctr/alpt/cold"]
        worse = {"cells": [dict(doc["cells"][0], cache_hit_rate=0.7)]}
        bad = gate.compare(base, {"BENCH_Y.json": worse})
        assert [f.metric for f in bad] == ["cache_hit_rate"]

    def test_perf_layer_wires_into_analysis(self, tmp_path):
        from repro.analysis.perf import run_perf_checks

        doc = _e2e_doc()
        (tmp_path / "BENCH_X.json").write_text(json.dumps(doc))
        base = gate.seed_baseline({"BENCH_X.json": doc})
        (tmp_path / "BENCH_BASELINE.json").write_text(json.dumps(base))
        assert run_perf_checks(root=tmp_path) == []
        (tmp_path / "BENCH_X.json").write_text(json.dumps(_e2e_doc(us=1e6)))
        report = tmp_path / "report.json"
        found = run_perf_checks(root=tmp_path, report_path=report)
        assert found and all(f.rule == "perf-regression" for f in found)
        assert json.loads(report.read_text())  # CI diff artifact written

    def test_no_baseline_means_pass(self, tmp_path):
        from repro.analysis.perf import run_perf_checks

        assert run_perf_checks(root=tmp_path) == []

    def test_committed_baseline_holds(self):
        """The repo's own committed baseline passes against its artifacts
        for everything deterministic (time cells are machine-relative, so
        they are exempt here — CI runs the full gate on its own numbers)."""
        from repro.analysis.lint import REPO_ROOT

        path = REPO_ROOT / "BENCH_BASELINE.json"
        if not path.exists():
            pytest.skip("no committed baseline")
        baseline = gate.load_baseline(path)
        fresh = gate.load_fresh(REPO_ROOT, baseline)
        hard = [
            f for f in gate.compare(baseline, fresh)
            if gate.classify(f.metric) not in ("time", "frac")
        ]
        assert hard == [], hard


# ----------------------------------------------------------- legacy shims


class TestLegacySchemas:
    def test_fallback_stats_keys(self):
        ops.reset_fallback_stats()
        stats = ops.fallback_stats()
        assert set(stats) == {"kernel_calls", "fallbacks", "total_fallbacks"}
        assert stats["total_fallbacks"] == 0
        assert stats["fallbacks"] == []

    def test_fallback_stats_reads_registry(self):
        ops.reset_fallback_stats()
        reg = obs_counters.registry()
        reg.counter("kernels.fallbacks",
                    labels=("op", "shape", "reason")).inc(
                        2, "dequant_gather", "(8, 8)", "test-reason")
        stats = ops.fallback_stats()
        assert stats["total_fallbacks"] == 2
        assert stats["fallbacks"] == [{
            "op": "dequant_gather", "shape": "(8, 8)",
            "reason": "test-reason", "count": 2,
        }]
        ops.reset_fallback_stats()

    def test_engine_metrics_legacy_keys(self):
        data = CTRSynthetic(OBS_DATA)
        tr = _trainer("alpt")
        engine = CTREngine.from_state(tr.init_state(), tr.cfg, batch=8)
        ids, _ = data.batch("test", 0, 8)
        for row in ids:
            engine.submit(CTRRequest(ids=row))
        engine.run()
        doc = engine.metrics().to_json()
        # the pre-registry schema, pinned: renames/removals break consumers
        for key in (
            "scenario", "embedding_method", "requests_submitted",
            "requests_completed", "steps", "wall_s",
            "resident_embedding_bytes", "embedding_code_bytes",
            "embedding_scale_bytes", "int8_resident", "kernel_fallbacks",
            "served_degraded", "deadline_misses", "wave_retries",
            "retry_failures", "us_per_request",
        ):
            assert key in doc, key
        assert doc["requests_completed"] == 8
        json.dumps(doc)
